"""Zamba2-1.2B hybrid (Mamba2 backbone + shared attention) [arXiv:2411.15242; hf].

38 Mamba2 layers, d_model 2048, ssm_state 64; a weight-shared transformer block
(32 heads MHA, d_ff 8192) is invoked every 6 mamba layers (simplified from
Zamba2's dual shared blocks + per-use LoRA — DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    hybrid_attn_every=6,
    rope_theta=1e4,
    norm_eps=1e-5,
))
