"""Docs stay truthful: every repo path referenced in README.md / docs/*.md
must exist in the tree (module renames may not silently rot the
architecture docs), and the checker itself must catch a dangling path."""
import importlib.util
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "check_doc_paths", ROOT / "tools" / "check_doc_paths.py")
check_doc_paths = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_doc_paths)


def test_doc_paths_exist():
    bad = check_doc_paths.check()
    assert not bad, "dangling doc references: " + ", ".join(
        f"{d} -> {p}" for d, p in bad)


def test_docs_exist_and_are_linked():
    assert (ROOT / "docs" / "ARCHITECTURE.md").exists()
    assert (ROOT / "docs" / "BENCHMARKS.md").exists()
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/BENCHMARKS.md" in readme


def test_checker_catches_dangling_path(tmp_path):
    doc = tmp_path / "BROKEN.md"
    doc.write_text("see `src/repro/core/does_not_exist.py` and "
                   "`.github/workflows/nope.yml` for details")
    bad = check_doc_paths.check([doc])
    assert {p for _, p in bad} == {"src/repro/core/does_not_exist.py",
                                   ".github/workflows/nope.yml"}
    ok = tmp_path / "OK.md"
    ok.write_text("CI lives in `.github/workflows/ci.yml`")
    assert check_doc_paths.check([ok]) == []
