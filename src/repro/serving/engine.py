"""Serving engine: prefill + CHUNKED decode with inter-chunk cancellation.

The paper's Fig-2 "termination signal" cannot preempt a launched XLA
program, so decode runs as jit'd chunks of K tokens (one dispatch each);
between chunks the host checks cancellation (StorInfer's vector-search hit)
and the session stops paying for further compute within <= one chunk.
The same structure gives continuous batching its insertion points.

Components:
  Engine          — jit'd prefill / decode-chunk programs for one config
  Session         — single-request chunked generation with .cancel()
  BatchScheduler  — fixed-slot continuous batching over a shared cache;
                    per-slot cancellation == StorInfer hit-cancellation
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tokenizer import EOS
from repro.models import model as M


def sample_token(logits, rng, temperature):
    lg = logits.astype(jnp.float32)
    if temperature is None:
        return jnp.argmax(lg, axis=-1)
    t = jnp.maximum(temperature, 1e-6)
    return jax.random.categorical(rng, lg / t, axis=-1)


class Engine:
    """One model, jit'd once; serves many sessions."""

    def __init__(self, cfg, params, tokenizer, run: M.RunCfg = None,
                 max_len: int = 256, chunk: int = 8):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.run = run or M.RunCfg(attn_impl="naive", remat=False)
        self.max_len = max_len
        self.chunk = chunk
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_chunk = jax.jit(self._decode_chunk_impl)
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=(0,))

    # -- jit bodies -----------------------------------------------------------
    def _prefill_impl(self, params, tokens):
        batch = {"tokens": tokens}
        logits, cache = M.prefill(self.cfg, params, batch, self.run,
                                  max_len=self.max_len)
        return logits, cache

    def _decode_chunk_impl(self, params, token, cache, cache_len, rng,
                           temperature, live):
        """Runs ``chunk`` decode steps. live: (B,) bool — dead slots decode
        but their cache writes are masked out (slot freed semantics)."""

        def body(carry, _):
            tok, cache, clen, rng = carry
            rng, sub = jax.random.split(rng)
            logits, new_cache = M.decode_step(self.cfg, params, tok, cache,
                                              clen, self.run)
            nxt = sample_token(logits[:, -1, :], sub, temperature)[:, None]
            nxt = nxt.astype(jnp.int32)
            keep = live[:, None]
            nxt = jnp.where(keep, nxt, tok)
            new_cache = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    jnp.reshape(live, (1, -1) + (1,) * (n.ndim - 2)), n, o),
                new_cache, cache)
            return (nxt, new_cache, clen + 1, rng), nxt[:, 0]

        (tok, cache, clen, _), toks = jax.lax.scan(
            body, (token, cache, cache_len, rng), None, length=self.chunk)
        return tok, cache, clen, jnp.transpose(toks)  # (B, chunk)

    def _write_slot_impl(self, batch_cache, one_cache, slot):
        """Insert a prefilled single-request cache at batch slot ``slot``."""

        def wr(bc, oc):
            return jax.lax.dynamic_update_slice(
                bc, oc.astype(bc.dtype),
                (0, slot) + (0,) * (bc.ndim - 2))

        return jax.tree_util.tree_map(wr, batch_cache, one_cache)

    # -- single-shot generation ------------------------------------------------
    def generate(self, prompt: str, max_new: int = 32, temperature=None,
                 seed: int = 0) -> str:
        s = self.start_session(prompt, max_new=max_new,
                               temperature=temperature, seed=seed)
        while not s.done:
            s.step_chunk()
        return s.text()


    def start_session(self, prompt: str, max_new: int = 32, temperature=None,
                      seed: int = 0) -> "Session":
        return Session(self, prompt, max_new, temperature, seed)


class Session:
    """Single-request chunked generation with host-side cancellation."""

    def __init__(self, engine: Engine, prompt: str, max_new, temperature,
                 seed):
        self.e = engine
        ids = engine.tok.encode(prompt, bos=True)[: engine.max_len - 1]
        tokens = jnp.asarray([ids], jnp.int32)
        t0 = time.perf_counter()
        logits, cache = engine._prefill(engine.params, tokens)
        self.prefill_s = time.perf_counter() - t0
        self.cache = cache
        self.cache_len = jnp.asarray(len(ids) - 1, jnp.int32)
        self.token = jnp.asarray(
            [[int(jnp.argmax(logits[0, -1]))]], jnp.int32)
        self.out_ids: List[int] = [int(self.token[0, 0])]
        self.max_new = max_new
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.cancelled = False
        self.decode_s = 0.0
        self.chunks_run = 0

    @property
    def done(self) -> bool:
        return (self.cancelled or len(self.out_ids) >= self.max_new
                or (self.out_ids and self.out_ids[-1] == EOS))

    def cancel(self):
        """The paper's termination signal (takes effect between chunks)."""
        self.cancelled = True

    def step_chunk(self):
        if self.done:
            return
        t0 = time.perf_counter()
        self.rng, sub = jax.random.split(self.rng)
        live = jnp.ones((1,), bool)
        self.token, self.cache, self.cache_len, toks = \
            self.e._decode_chunk(self.e.params, self.token, self.cache,
                                 self.cache_len + 1, sub,
                                 self.temperature, live)
        self.cache_len = self.cache_len - 1
        toks = np.asarray(toks[0])
        for t in toks:
            if len(self.out_ids) >= self.max_new or t == EOS:
                break
            self.out_ids.append(int(t))
        self.decode_s += time.perf_counter() - t0
        self.chunks_run += 1

    def text(self) -> str:
        return self.e.tok.decode(self.out_ids)


# ---------------------------------------------------------------------------
# Continuous batching with per-slot (hit-)cancellation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new: int = 32
    temperature: Optional[float] = None
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    slot: int = -1


class BatchScheduler:
    """Fixed B slots over one shared batched cache; requests enter on free
    slots (prefill -> slot write), leave on EOS/max/cancel. Cancellation is
    the StorInfer hit path: the slot is freed at the next chunk boundary."""

    def __init__(self, engine: Engine, batch_size: int = 4):
        self.e = engine
        self.B = batch_size
        cfg = engine.cfg
        self.cache = M.init_cache(cfg, batch_size, engine.max_len)
        self.token = jnp.zeros((batch_size, 1), jnp.int32)
        self.live = np.zeros(batch_size, bool)
        self.reqs: List[Optional[Request]] = [None] * batch_size
        self.cache_len = jnp.asarray(0, jnp.int32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self.rng = jax.random.PRNGKey(0)

    def submit(self, req: Request):
        self.waiting.append(req)

    def cancel(self, rid: int):
        for r in self.reqs:
            if r is not None and r.rid == rid:
                r.cancelled = True
        for r in self.waiting:
            if r.rid == rid:
                r.cancelled = True

    def _admit(self):
        for slot in range(self.B):
            if self.live[slot] or not self.waiting:
                continue
            req = self.waiting.pop(0)
            if req.cancelled:
                req.done = True
                self.finished.append(req)
                continue
            ids = self.e.tok.encode(req.prompt, bos=True)
            ids = ids[: self.e.max_len - req.max_new - 1]
            tokens = jnp.asarray([ids], jnp.int32)
            logits, one_cache = self.e._prefill(self.e.params, tokens)
            self.cache = self.e._write_slot(self.cache, one_cache,
                                            jnp.asarray(slot, jnp.int32))
            first = int(jnp.argmax(logits[0, -1]))
            req.out_ids.append(first)
            req.slot = slot
            self.token = self.token.at[slot, 0].set(first)
            self.live[slot] = True
            self.reqs[slot] = req
            # NOTE: single shared cache_len => scheduler admits requests of
            # equal prompt length per batch wave (padded upstream); the
            # dry-run decode path uses per-slot lengths via seq-sharded
            # attention masks instead.
            self.cache_len = jnp.asarray(len(ids) - 1, jnp.int32)

    def _retire(self):
        for slot in range(self.B):
            r = self.reqs[slot]
            if r is None:
                continue
            if (r.cancelled or len(r.out_ids) >= r.max_new
                    or (r.out_ids and r.out_ids[-1] == EOS)):
                r.done = True
                self.finished.append(r)
                self.reqs[slot] = None
                self.live[slot] = False

    def step_chunk(self):
        self._admit()
        self._retire()
        if not self.live.any():
            return False
        self.rng, sub = jax.random.split(self.rng)
        temps = [r.temperature for r in self.reqs if r is not None]
        temp = temps[0] if temps and temps[0] is not None else None
        self.token, self.cache, self.cache_len, toks = self.e._decode_chunk(
            self.e.params, self.token, self.cache, self.cache_len + 1, sub,
            temp, jnp.asarray(self.live))
        self.cache_len = self.cache_len - 1
        toks = np.asarray(toks)
        for slot in range(self.B):
            r = self.reqs[slot]
            if r is None:
                continue
            for t in toks[slot]:
                if len(r.out_ids) >= r.max_new or t == EOS:
                    break
                r.out_ids.append(int(t))
        self._retire()
        return True

    def run_to_completion(self, max_chunks=1000):
        for _ in range(max_chunks):
            self._admit()
            if not self.step_chunk() and not self.waiting:
                break
        return self.finished
