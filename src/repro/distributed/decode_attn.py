"""KV-sequence-sharded decode attention (TPU flash-decoding) via shard_map.

At 32k-500k context the KV cache cannot live on one chip and GQA kv-head
counts (4-16) don't divide a 16-way model axis — so the decode cache shards
along the SEQUENCE dim over "model". Each device:

  1. updates its local cache slice iff the global write position lands in it,
  2. computes partial attention (o, m, l) over its KV slice,
  3. combines with the max-rescale trick: one pmax + two psums over "model".

This is the explicit-collective equivalent of flash-decoding; GSPMD cannot
derive it automatically (a sharded-softmax over a dynamic-length axis), which
is why this is a shard_map and not an annotation.

All functions take/return GLOBAL arrays and must be called under the mesh
(inside jit with sharded operands or eagerly with committed arrays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

NEG_INF = -1e30


def _local_update(cache, new, global_idx, seq_axis, n_shards):
    """Write ``new`` (B,1,...) at global seq position inside a shard_map."""
    M_local = cache.shape[1]
    shard = jax.lax.axis_index(seq_axis)
    start = shard * M_local
    loc = global_idx - start
    in_range = (loc >= 0) & (loc < M_local)
    loc_c = jnp.clip(loc, 0, M_local - 1)
    zeros = (0,) * (cache.ndim - 2)
    old = jax.lax.dynamic_slice(
        cache, (0, loc_c) + zeros, (cache.shape[0], 1) + cache.shape[2:])
    upd = jnp.where(in_range, new.astype(cache.dtype), old)
    return jax.lax.dynamic_update_slice(cache, upd, (0, loc_c) + zeros), start


def _combine(o, m, l, seq_axis):
    """(o,m,l) partial flash stats -> combined output over ``seq_axis``."""
    m_g = jax.lax.pmax(m, seq_axis)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, seq_axis)
    o_g = jax.lax.psum(o * corr[..., None].astype(o.dtype), seq_axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None].astype(o.dtype)


def gqa_decode_seq_sharded(q, k_new, v_new, kc, vc, cache_len, *, mesh,
                           seq_axis="model", batch_axes=("data",)):
    """One-token GQA decode over a seq-sharded cache.

    q      : (B, 1, Hq, D)   — replicated over ``seq_axis``
    k_new  : (B, 1, Hkv, D)  — this step's key (pre-roped)
    v_new  : (B, 1, Hkv, D)
    kc, vc : (B, M, Hkv, D)  — M sharded over ``seq_axis``
    cache_len: ()            — global write/attend position

    Returns (out (B,1,Hq*D), kc', vc').
    """
    B, _, Hq, D = q.shape
    Hkv = kc.shape[2]
    G = Hq // Hkv
    n_shards = mesh.shape[seq_axis]
    scale = D ** -0.5
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    if B % max(1, _prod(mesh, b_axes)) != 0:
        bspec = None

    def local(q, k_new, v_new, kc, vc, cache_len):
        kc, start = _local_update(kc, k_new, cache_len, seq_axis, n_shards)
        vc, _ = _local_update(vc, v_new, cache_len, seq_axis, n_shards)
        Ml = kc.shape[1]
        pos = start + jnp.arange(Ml)
        qg = q.reshape(q.shape[0], Hkv, G, D)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, kc).astype(jnp.float32) * scale
        s = jnp.where((pos <= cache_len)[None, None, None, :], s, NEG_INF)
        m = s.max(-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(-1)
        o = jnp.einsum("bkgt,btkv->bkgv", p.astype(vc.dtype), vc)
        out = _combine(o, m, l, seq_axis)                   # (b,Hkv,G,D)
        return out.reshape(out.shape[0], 1, Hq * D), kc, vc

    sm = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec),
                  P(bspec, seq_axis), P(bspec, seq_axis), P()),
        out_specs=(P(bspec), P(bspec, seq_axis), P(bspec, seq_axis)),
        check_vma=False)
    return sm(q, k_new, v_new, kc, vc, cache_len)


def mla_decode_seq_sharded(q_c, q_r, ckv_new, krope_new, ckv_c, krope_c,
                           cache_len, scale, *, mesh, seq_axis="model",
                           batch_axes=("data",)):
    """Absorbed-MLA decode over a seq-sharded compressed cache.

    q_c: (B,1,H,r); q_r: (B,1,H,dr); ckv_new: (B,1,r); krope_new: (B,1,dr);
    ckv_c: (B,M,r); krope_c: (B,M,dr). Returns (out_c (B,1,H,r), ckv', krope').
    """
    B, _, H, r = q_c.shape
    n_shards = mesh.shape[seq_axis]
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    if B % max(1, _prod(mesh, b_axes)) != 0:
        bspec = None

    def local(q_c, q_r, ckv_new, krope_new, ckv_c, krope_c, cache_len):
        ckv_c, start = _local_update(ckv_c, ckv_new, cache_len, seq_axis,
                                     n_shards)
        krope_c, _ = _local_update(krope_c, krope_new, cache_len, seq_axis,
                                   n_shards)
        Ml = ckv_c.shape[1]
        pos = start + jnp.arange(Ml)
        s = (jnp.einsum("bshr,btr->bhst", q_c, ckv_c)
             + jnp.einsum("bshr,btr->bhst", q_r, krope_c))
        s = s.astype(jnp.float32) * scale                  # (b,H,1,Ml)
        s = jnp.where((pos <= cache_len)[None, None, None, :], s, NEG_INF)
        m = s.max(-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(-1)
        o = jnp.einsum("bhst,btr->bhsr", p.astype(ckv_c.dtype), ckv_c)
        out = _combine(o, m, l, seq_axis)                  # (b,H,1,r)
        return jnp.moveaxis(out, 1, 2), ckv_c, krope_c     # (b,1,H,r)

    sm = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), P(bspec),
                  P(bspec, seq_axis), P(bspec, seq_axis), P()),
        out_specs=(P(bspec), P(bspec, seq_axis), P(bspec, seq_axis)),
        check_vma=False)
    return sm(q_c, q_r, ckv_new, krope_new, ckv_c, krope_c, cache_len)


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
