"""Core layer primitives: init helpers, norms, MLPs, RoPE/M-RoPE, GQA attention.

Everything is a pure function over dict pytrees — no framework dependency.
Shapes use B=batch, S=query length, T=key length, H=heads, K=kv heads, D=head dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(scale, x, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def _rmsnorm_fwd(scale, x, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)                       # f32 (..., 1)
    y = x * inv.astype(x.dtype) * scale.astype(x.dtype)
    return y, (scale, x, inv)


def _rmsnorm_bwd(eps, res, dy):
    # Custom VJP so the residual is (x bf16, inv f32[...,1]) — plain AD of
    # square(x.astype(f32)) saves the f32 UPCAST of x, which XLA then hoists
    # into the layer-scan residual stack: every layer input stored twice
    # (bf16 + f32; measured +6.4 GB/device on grok-1 train_4k).
    scale, x, inv = res
    xf = x.astype(jnp.float32)
    g = dy.astype(jnp.float32) * scale.astype(jnp.float32)
    proj = jnp.mean(g * xf, axis=-1, keepdims=True)
    dx = inv * g - xf * (inv ** 3) * proj
    dscale = jnp.sum(dy.astype(jnp.float32) * xf * inv,
                     axis=tuple(range(x.ndim - 1)))
    return dscale.astype(scale.dtype), dx.astype(x.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p, x, eps=1e-6):
    return _rmsnorm_core(p["scale"], x, eps)


def gated_rmsnorm(p, x, z, eps=1e-6):
    """Mamba2-style gated norm: rmsnorm(x * silu(z))."""
    return rmsnorm(p, x * jax.nn.silu(z), eps)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg, d_ff=None, dtype=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": dense_init(k1, d, ff, dtype),
         "w2": dense_init(k2, ff, d, dtype)}
    if cfg.gated_mlp:
        p["w3"] = dense_init(k3, d, ff, dtype)
    return p


def mlp(cfg, p, x):
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(dense(p["w1"], x))
    if cfg.gated_mlp:
        h = h * dense(p["w3"], x)
    return dense(p["w2"], h)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, D); positions: (B, S) int32."""
    D = x.shape[-1]
    inv = jnp.asarray(rope_freqs(D, theta))             # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta, sections):
    """M-RoPE (Qwen2-VL): positions (3, B, S) for t/h/w; ``sections`` partitions
    the D/2 frequency slots among the three position streams."""
    D = x.shape[-1]
    inv = jnp.asarray(rope_freqs(D, theta))             # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, D/2)
    # select which position stream (t/h/w) drives each frequency slot
    sec_id = np.repeat(np.arange(3), np.asarray(sections))   # (D/2,)
    onehot = jax.nn.one_hot(jnp.asarray(sec_id), 3, dtype=jnp.float32)  # (D/2, 3)
    angles = jnp.einsum("tbsd,dt->bsd", angles, onehot)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len, d_model):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / (10000 ** (dim / d_model))
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Attention (GQA reference path; Pallas kernels live in repro.kernels)
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype=None):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype, bias=cfg.attn_bias),
         "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype, bias=cfg.attn_bias),
         "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype, bias=cfg.attn_bias),
         "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def gqa_scores_softmax_out(q, k, v, mask, scale):
    """q: (B,S,Hq,D) k,v: (B,T,Hkv,D[v]), mask: broadcastable (B,1,1,S,T) or None.

    Returns (B,S,Hq,Dv). Softmax in f32. Pure-jnp reference path (the Pallas
    flash kernels in repro.kernels implement the same contract).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkv->bskgv", probs.astype(v.dtype), v)
    return out.reshape(B, S, Hq, v.shape[-1])


def causal_mask(S, T, offset):
    """Query i (global pos offset+i) may attend key j iff j <= offset + i."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    return (j <= (i + offset))[None, None, None, :, :]


def attention(cfg, p, x, positions, *, mask_offset=0, kv_cache=None,
              cache_len=None, mrope_positions=None):
    """Full attention for train/prefill (kv_cache None) or decode (kv_cache set).

    kv_cache: dict {"k": (B, Smax, Hkv, D), "v": ...} — decode writes the new
    token at position ``cache_len`` and attends to [0, cache_len].
    Returns (out, new_kv) where new_kv is the (k, v) of this call's tokens for
    cache construction (prefill) or the updated cache (decode).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_kind == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    scale = hd ** -0.5

    if kv_cache is None:  # train / prefill: causal over own tokens
        mask = causal_mask(S, S, mask_offset)
        out = gqa_scores_softmax_out(q, k, v, mask, scale)
        new_kv = {"k": k, "v": v}
    else:  # decode: S == 1
        kc = jax.lax.dynamic_update_slice(
            kv_cache["k"], k, (0, cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            kv_cache["v"], v, (0, cache_len, 0, 0))
        T = kc.shape[1]
        mask = (jnp.arange(T)[None, :] <= cache_len)[None, None, None, None, :]
        out = gqa_scores_softmax_out(q, kc, vc, mask, scale)
        new_kv = {"k": kc, "v": vc}
    return dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd)), new_kv


def cross_attention_init(key, cfg, dtype=None):
    return attention_init(key, cfg, dtype)


def cross_attention(cfg, p, x, enc_out):
    """Decoder cross-attention over encoder outputs (no mask, no rope)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], enc_out).reshape(B, enc_out.shape[1], cfg.n_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(B, enc_out.shape[1], cfg.n_kv_heads, hd)
    out = gqa_scores_softmax_out(q, k, v, None, hd ** -0.5)
    return dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
