"""Model assembly: init / forward / prefill / decode for every assigned family.

Families: dense (llama / qwen / starcoder), vlm (dense + M-RoPE backbone),
moe (deepseek MLA+MoE, grok GQA+MoE), ssm (mamba2), hybrid (zamba2: mamba
backbone + shared attention block), encdec (whisper backbone).

Conventions
-----------
* Params are dict pytrees; uniform layer stacks are STACKED on a leading L
  axis (init via ``jax.vmap``) and applied with ``lax.scan`` (+ optional
  remat) — constant compile size at any depth. Hybrid (38L, non-uniform) and
  whisper (6+6L) apply their stacked params with a Python loop.
* ``RunCfg`` carries implementation choices (attention schedule, MoE
  dispatch, decode sharding) so the same model code serves smoke tests,
  the 512-device dry-run, and the §Perf hillclimb variants.
* Full-seq attention defaults to the blockwise flash path (never
  materializes S x T); ``naive`` is the small-shape oracle.

Cache layouts (leading dim = layer / invocation):
  GQA   : {"k": (L,B,M,Hkv,Dh), "v": (L,B,M,Hkv,Dh)}
  MLA   : {"ckv": (L,B,M,r), "krope": (L,B,M,dr)}   (compressed; absorbed decode)
  SSM   : {"h": (L,B,H,P,N) f32, "conv": (L,B,W-1,C)}
  hybrid: SSM + {"ak"/"av": (I,B,M,Hkv,Dh)}  I = #shared-attn invocations
  encdec: GQA self + {"xk"/"xv": (L,B,Tenc,H,Dh)} cross (static after prefill)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as Lyr
from repro.models import mla as Mla
from repro.models import moe as Moe
from repro.models import ssm as Ssm
from repro.models.blockwise import blockwise_gqa


# ---------------------------------------------------------------------------
# Run configuration (implementation knobs, not architecture)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunCfg:
    attn_impl: str = "blockwise"      # naive | blockwise
    schedule: str = "rect"            # rect | tri  (causal block skipping)
    q_block: int = 512
    kv_block: int = 1024
    moe_impl: str = "scatter"         # scatter | einsum | ep
    moe_group: int = 2048
    remat: bool = True
    scan_layers: bool = True
    decode_attn: str = "naive"        # naive | seq_sharded
    mesh: Any = None                  # jax Mesh for shard_map paths
    ep_axis: str = "model"
    seq_axis: str = "model"
    batch_axes: Tuple[str, ...] = ("data",)
    aux_coef: float = 0.01
    logits_f32: bool = False          # cast logits to f32 (loss is f32 anyway)
    heads_sharded: bool = False       # q-heads TP-shard over "model"
    repeat_kv: bool = False           # Megatron-GQA: kv replicated+repeated
    ssm_chunk: int = 0                # override cfg.ssm_chunk (0 = cfg's);
                                      # SSD chunking is exact at any size —
                                      # this is a memory/compute tile knob
    seq_parallel: bool = False        # Megatron-SP: residual stream sharded
                                      # over ("model", seq) between layers —
                                      # GSPMD derives RS+AG instead of AR
    pin_ssm: bool = False             # pin SSD internals to batch-only
                                      # sharding (stops GSPMD speculative
                                      # seq-sharding -> halo permutes)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


SMOKE = RunCfg(attn_impl="naive", remat=False, q_block=64, kv_block=64,
               moe_group=64)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg, dtype):
    if cfg.use_mla:
        return Mla.mla_init(key, cfg, dtype)
    return Lyr.attention_init(key, cfg, dtype)


def init_block(key, cfg, kind, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if kind == "dense":
        return {"ln1": Lyr.rmsnorm_init(d, dtype),
                "attn": _attn_init(ks[0], cfg, dtype),
                "ln2": Lyr.rmsnorm_init(d, dtype),
                "mlp": Lyr.mlp_init(ks[1], cfg, dtype=dtype)}
    if kind == "moe":
        return {"ln1": Lyr.rmsnorm_init(d, dtype),
                "attn": _attn_init(ks[0], cfg, dtype),
                "ln2": Lyr.rmsnorm_init(d, dtype),
                "moe": Moe.moe_init(ks[1], cfg, dtype)}
    if kind == "moe_dense0":  # deepseek leading dense layer
        return {"ln1": Lyr.rmsnorm_init(d, dtype),
                "attn": _attn_init(ks[0], cfg, dtype),
                "ln2": Lyr.rmsnorm_init(d, dtype),
                "mlp": Lyr.mlp_init(ks[1], cfg, d_ff=cfg.d_ff_dense or cfg.d_ff,
                                    dtype=dtype)}
    if kind == "ssm":
        return {"ln": Lyr.rmsnorm_init(d, dtype),
                "ssm": Ssm.ssm_init(ks[0], cfg, dtype)}
    if kind == "enc":
        return {"ln1": Lyr.rmsnorm_init(d, dtype),
                "attn": Lyr.attention_init(ks[0], cfg, dtype),
                "ln2": Lyr.rmsnorm_init(d, dtype),
                "mlp": Lyr.mlp_init(ks[1], cfg, dtype=dtype)}
    if kind == "dec":
        return {"ln1": Lyr.rmsnorm_init(d, dtype),
                "attn": Lyr.attention_init(ks[0], cfg, dtype),
                "lnx": Lyr.rmsnorm_init(d, dtype),
                "xattn": Lyr.cross_attention_init(ks[1], cfg, dtype),
                "ln2": Lyr.rmsnorm_init(d, dtype),
                "mlp": Lyr.mlp_init(ks[2], cfg, dtype=dtype)}
    raise ValueError(kind)


def _stack_init(key, cfg, kind, n, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_block(k, cfg, kind, dtype))(keys)


def main_block_kind(cfg) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe", "ssm": "ssm",
            "hybrid": "ssm", "encdec": "dec"}[cfg.family]


def n_shared_attn(cfg) -> int:
    """# shared-attention invocations in a hybrid stack (layers i%k==0)."""
    k = cfg.hybrid_attn_every
    return -(-cfg.n_layers // k) if k else 0


def init_model(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab
    params = {
        "embed": {"w": (jax.random.normal(ks[0], (vp, cfg.d_model),
                                          jnp.float32)
                        * cfg.d_model ** -0.5).astype(dtype)},
        "final_norm": Lyr.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Lyr.dense_init(ks[1], cfg.d_model, vp, dtype)
    kind = main_block_kind(cfg)
    n_main = cfg.n_layers - (cfg.first_dense_layers if cfg.family == "moe" else 0)
    params["blocks"] = _stack_init(ks[2], cfg, kind, n_main, dtype)
    if cfg.family == "moe" and cfg.first_dense_layers:
        params["dense0"] = _stack_init(ks[3], cfg, "moe_dense0",
                                       cfg.first_dense_layers, dtype)
    if cfg.family == "hybrid":
        params["shared"] = init_block(ks[4], cfg, "dense", dtype)
    if cfg.is_encoder_decoder:
        params["enc_blocks"] = _stack_init(ks[5], cfg, "enc",
                                           cfg.n_encoder_layers, dtype)
        params["enc_norm"] = Lyr.rmsnorm_init(cfg.d_model, dtype)
    return params


def count_params(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Attention (full sequence): projection + impl dispatch
# ---------------------------------------------------------------------------


def _rope_q_k(cfg, p, q, k, positions, mrope_positions):
    if cfg.qk_norm:
        q = Lyr.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = Lyr.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_kind == "standard":
        q = Lyr.apply_rope(q, positions, cfg.rope_theta)
        k = Lyr.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = Lyr.apply_mrope(q, mrope_positions, cfg.rope_theta,
                            cfg.mrope_sections)
        k = Lyr.apply_mrope(k, mrope_positions, cfg.rope_theta,
                            cfg.mrope_sections)
    return q, k


def _batch_cb(run):
    """Sharding-constraint callback for blockwise attention tiles: pins the
    batch dim to the batch axes and (when q-heads are TP-sharded) the head
    dim to "model" — with_sharding_constraint treats unlisted dims as
    replicated, so the head dim must be named explicitly or the constraint
    itself would gather head-sharded tiles."""
    if run.mesh is None:
        return None

    def cb(t, bdim, hdim=None):
        spec = [None] * t.ndim
        spec[bdim] = run.batch_axes
        if run.heads_sharded and hdim is not None:
            spec[hdim] = "model"
        return _constrain(t, run, *spec)

    return cb


def gqa_fullseq(cfg, run, p, x, positions, *, mrope_positions=None,
                mask_offset=0, causal=True):
    """Returns (out (B,S,d), kv dict) for train/prefill."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = Lyr.dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = Lyr.dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = Lyr.dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    q, k = _rope_q_k(cfg, p, q, k, positions, mrope_positions)
    G = cfg.n_heads // cfg.n_kv_heads
    ka, va = k, v
    if run.repeat_kv and G > 1:
        # Megatron-GQA: kv heads replicated over "model"; repeat to full
        # head count so the attention einsums shard cleanly on q-heads.
        ka = jnp.repeat(k, G, axis=2)
        va = jnp.repeat(v, G, axis=2)
    if run.attn_impl == "naive":
        mask = Lyr.causal_mask(S, S, mask_offset) if causal else None
        out = Lyr.gqa_scores_softmax_out(q, ka, va, mask, hd ** -0.5)
    else:
        out = blockwise_gqa(q, ka, va, causal=causal, mask_offset=mask_offset,
                            q_block=run.q_block, kv_block=run.kv_block,
                            schedule=run.schedule, constrain=_batch_cb(run))
    return Lyr.dense(p["wo"], out.reshape(B, S, cfg.n_heads * hd)), \
        {"k": k, "v": v}


def mla_fullseq(cfg, run, p, x, positions, *, mask_offset=0):
    """MLA train/prefill: expand compressed KV per head, blockwise attention.

    Returns (out, {"ckv","krope"}) — the cache stays compressed.
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qn, qr = Mla._project_q(cfg, p, x)
    qr = Lyr.apply_rope(qr, positions, cfg.rope_theta)
    ckv, krope = Mla._project_ckv(cfg, p, x, positions)
    kn = jnp.einsum("bsr,rhn->bshn", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhv->bshv", ckv, p["wuv"])
    q = jnp.concatenate([qn, qr], axis=-1)                     # (B,S,H,nope+rd)
    kr = jnp.broadcast_to(krope, (B, S, H, rd))
    k = jnp.concatenate([kn, kr], axis=-1)
    if run.attn_impl == "naive":
        mask = Lyr.causal_mask(S, S, mask_offset)
        out = Lyr.gqa_scores_softmax_out(q, k, v, mask, (nope + rd) ** -0.5)
    else:
        out = blockwise_gqa(q, k, v, causal=True, mask_offset=mask_offset,
                            q_block=run.q_block, kv_block=run.kv_block,
                            schedule=run.schedule, constrain=_batch_cb(run))
    return Lyr.dense(p["wo"], out.reshape(B, S, H * vd)), \
        {"ckv": ckv, "krope": krope[:, :, 0, :]}


def attn_fullseq(cfg, run, p, x, positions, **kw):
    if cfg.use_mla:
        kw.pop("mrope_positions", None)
        kw.pop("causal", None)
        return mla_fullseq(cfg, run, p, x, positions, **kw)
    return gqa_fullseq(cfg, run, p, x, positions, **kw)


# ---------------------------------------------------------------------------
# Attention (single-token decode)
# ---------------------------------------------------------------------------


def _cache_update(cache, new, idx):
    """Write ``new`` (B,1,...) at position idx of cache (B,M,...)."""
    zeros = (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (0, idx) + zeros)


def gqa_decode(cfg, run, p, x, kc, vc, cache_len, *, mrope_positions=None):
    """x (B,1,d); kc/vc (B,M,Hkv,Dh). Returns (out, new_kc, new_vc)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q = Lyr.dense(p["wq"], x).reshape(B, 1, Hq, hd)
    k = Lyr.dense(p["wk"], x).reshape(B, 1, Hkv, hd)
    v = Lyr.dense(p["wv"], x).reshape(B, 1, Hkv, hd)
    if mrope_positions is None and cfg.rope_kind == "mrope":
        mrope_positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k = _rope_q_k(cfg, p, q, k, positions, mrope_positions)

    if run.decode_attn == "seq_sharded" and run.mesh is not None:
        from repro.distributed.decode_attn import gqa_decode_seq_sharded
        out, kc, vc = gqa_decode_seq_sharded(
            q, k, v, kc, vc, cache_len, mesh=run.mesh,
            seq_axis=run.seq_axis, batch_axes=run.batch_axes)
    else:
        kc = _cache_update(kc, k, cache_len)
        vc = _cache_update(vc, v, cache_len)
        G = Hq // Hkv
        qg = q.reshape(B, Hkv, G, hd)
        logits = jnp.einsum("bkgd,btkd->bkgt", qg, kc).astype(jnp.float32)
        T = kc.shape[1]
        mask = (jnp.arange(T) <= cache_len)[None, None, None, :]
        logits = jnp.where(mask, logits * hd ** -0.5, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgt,btkv->bkgv", probs.astype(vc.dtype), vc)
        out = out.reshape(B, 1, Hq * hd)
    return Lyr.dense(p["wo"], out.reshape(B, 1, Hq * hd)), kc, vc


def mla_decode(cfg, run, p, x, ckv_c, krope_c, cache_len):
    """Absorbed decode over the compressed cache (B,M,r)/(B,M,dr)."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    qn, qr = Mla._project_q(cfg, p, x)
    qr = Lyr.apply_rope(qr, positions, cfg.rope_theta)
    ckv_new, krope_new = Mla._project_ckv(cfg, p, x, positions)
    q_c = jnp.einsum("bshn,rhn->bshr", qn, p["wuk"])
    scale = (nope + rd) ** -0.5

    if run.decode_attn == "seq_sharded" and run.mesh is not None:
        from repro.distributed.decode_attn import mla_decode_seq_sharded
        out_c, ckv_c, krope_c = mla_decode_seq_sharded(
            q_c, qr, ckv_new, krope_new[:, :, 0, :], ckv_c, krope_c,
            cache_len, scale, mesh=run.mesh, seq_axis=run.seq_axis,
            batch_axes=run.batch_axes)
    else:
        ckv_c = _cache_update(ckv_c, ckv_new, cache_len)
        krope_c = _cache_update(krope_c, krope_new[:, :, 0, :], cache_len)
        T = ckv_c.shape[1]
        logits = (jnp.einsum("bshr,btr->bhst", q_c, ckv_c)
                  + jnp.einsum("bshr,btr->bhst", qr, krope_c))
        logits = logits.astype(jnp.float32)
        mask = (jnp.arange(T) <= cache_len)[None, None, None, :]
        logits = jnp.where(mask, logits * scale, -1e30)
        probs = jax.nn.softmax(logits, -1).astype(ckv_c.dtype)
        out_c = jnp.einsum("bhst,btr->bshr", probs, ckv_c)
    out = jnp.einsum("bshr,rhv->bshv", out_c, p["wuv"])
    return Lyr.dense(p["wo"], out.reshape(B, 1, H * vd)), ckv_c, krope_c


# ---------------------------------------------------------------------------
# FFN dispatch
# ---------------------------------------------------------------------------


def apply_moe(cfg, run, p, x):
    if run.moe_impl == "einsum":
        return Moe.moe_ffn_einsum(cfg, p, x, run.moe_group)
    if run.moe_impl == "ep":
        from repro.distributed.moe_parallel import moe_ffn_ep
        return moe_ffn_ep(cfg, p, x, mesh=run.mesh, ep_axis=run.ep_axis,
                          batch_axes=run.batch_axes)
    return Moe.moe_ffn(cfg, p, x)


# ---------------------------------------------------------------------------
# Blocks (full sequence)
# ---------------------------------------------------------------------------


def block_fullseq(cfg, run, p, x, positions, *, kind, mrope_positions=None,
                  enc_out=None, mask_offset=0):
    """One layer. Returns (x, aux_loss, kv_dict_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "moe_dense0", "enc", "dec"):
        h, kv = attn_fullseq(cfg, run, p["attn"], Lyr.rmsnorm(p["ln1"], x,
                                                              cfg.norm_eps),
                             positions, mrope_positions=mrope_positions,
                             mask_offset=mask_offset,
                             causal=(kind != "enc"))
        x = x + h
        if kind == "dec":
            B, S = x.shape[:2]
            Te = enc_out.shape[1]
            hd = cfg.resolved_head_dim
            xq = Lyr.rmsnorm(p["lnx"], x, cfg.norm_eps)
            q = Lyr.dense(p["xattn"]["wq"], xq).reshape(B, S, cfg.n_heads, hd)
            xk = Lyr.dense(p["xattn"]["wk"], enc_out).reshape(
                B, Te, cfg.n_kv_heads, hd)
            xv = Lyr.dense(p["xattn"]["wv"], enc_out).reshape(
                B, Te, cfg.n_kv_heads, hd)
            if run.attn_impl == "naive":
                xa = Lyr.gqa_scores_softmax_out(q, xk, xv, None, hd ** -0.5)
            else:
                xa = blockwise_gqa(q, xk, xv, causal=False,
                                   q_block=run.q_block, kv_block=run.kv_block,
                                   constrain=_batch_cb(run))
            x = x + Lyr.dense(p["xattn"]["wo"],
                              xa.reshape(B, S, cfg.n_heads * hd))
            kv = dict(kv)
            kv["xk"], kv["xv"] = xk, xv  # static cross K/V for the cache
        if kind == "moe":
            h2, aux = apply_moe(cfg, run, p["moe"],
                                Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps))
        else:
            h2 = Lyr.mlp(cfg, p["mlp"], Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x + h2, aux, kv
    if kind == "ssm":
        cb = _batch_cb(run) if run.pin_ssm else None
        h, state = Ssm.ssm_forward(cfg, p["ssm"],
                                   Lyr.rmsnorm(p["ln"], x, cfg.norm_eps),
                                   chunk=run.ssm_chunk or None,
                                   constrain=cb)
        return x + h, aux, {"h": state[0], "conv": state[1]}
    raise ValueError(kind)


def block_decode(cfg, run, p, x, cache_sl, cache_len, *, kind,
                 mrope_positions=None):
    """One layer, one token. cache_sl = this layer's cache slice (no L dim)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "moe_dense0", "dec"):
        xin = Lyr.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.use_mla:
            h, ckv, krope = mla_decode(cfg, run, p["attn"], xin,
                                       cache_sl["ckv"], cache_sl["krope"],
                                       cache_len)
            new_cache = {"ckv": ckv, "krope": krope}
        else:
            h, kc, vc = gqa_decode(cfg, run, p["attn"], xin, cache_sl["k"],
                                   cache_sl["v"], cache_len,
                                   mrope_positions=mrope_positions)
            new_cache = {"k": kc, "v": vc}
        x = x + h
        if kind == "dec":
            B = x.shape[0]
            hd = cfg.resolved_head_dim
            xq = Lyr.rmsnorm(p["lnx"], x, cfg.norm_eps)
            q = Lyr.dense(p["xattn"]["wq"], xq).reshape(B, 1, cfg.n_heads, hd)
            out = Lyr.gqa_scores_softmax_out(q, cache_sl["xk"], cache_sl["xv"],
                                             None, hd ** -0.5)
            x = x + Lyr.dense(p["xattn"]["wo"],
                              out.reshape(B, 1, cfg.n_heads * hd))
            new_cache["xk"], new_cache["xv"] = cache_sl["xk"], cache_sl["xv"]
        if kind == "moe":
            h2, aux = apply_moe(cfg, run, p["moe"],
                                Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps))
        else:
            h2 = Lyr.mlp(cfg, p["mlp"], Lyr.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x + h2, new_cache
    if kind == "ssm":
        h, (hs, conv) = Ssm.ssm_decode(cfg, p["ssm"],
                                       Lyr.rmsnorm(p["ln"], x, cfg.norm_eps),
                                       cache_sl["h"], cache_sl["conv"])
        return x + h, {"h": hs, "conv": conv}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens, dtype=None):
    return jnp.take(params["embed"]["w"], tokens, axis=0)


def _constrain(x, run, *spec):
    """Sharding constraint honoring divisibility (no-op without a mesh)."""
    if run.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec

    def size(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= run.mesh.shape[a]
        return n

    spec = tuple(ax if dim % size(ax) == 0 else None
                 for dim, ax in zip(x.shape, spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(run.mesh, PartitionSpec(*spec)))


def lm_logits(cfg, run, params, x):
    x = Lyr.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].T
    else:
        logits = Lyr.dense(params["lm_head"], x)
    if cfg.padded_vocab != cfg.vocab_size:   # mask Megatron vocab padding
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    # keep the vocab dim model-sharded: without this GSPMD tends to gather
    # the full (B,S,V) logits per device (tens of GB at 1M tokens).
    logits = _constrain(logits, run, run.batch_axes, None, "model")
    return logits.astype(jnp.float32) if run.logits_f32 else logits


# ---------------------------------------------------------------------------
# Forward (train) / prefill
# ---------------------------------------------------------------------------


def _positions(batch, tokens):
    if "positions" in batch:
        return batch["positions"]
    B, S = tokens.shape
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def _encode(cfg, run, params, frames):
    """Whisper encoder over (stub-)precomputed frame embeddings."""
    x = frames + Lyr.sinusoidal_positions(frames.shape[1],
                                          cfg.d_model)[None].astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32)[None],
                           frames.shape[:2])
    L = cfg.n_encoder_layers
    for i in range(L):
        p = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
        x = _constrain(x, run, run.batch_axes, None, None)
        x, _, _ = block_fullseq(cfg, run, p, x, pos, kind="enc")
    return Lyr.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


@jax.custom_vjp
def _grad_safe_barrier(x):
    # optimization_barrier has no differentiation rule on older jax; give
    # it an identity VJP (the barrier is a scheduling fence, gradient-wise
    # it IS the identity) so training paths can differentiate through it.
    return jax.lax.optimization_barrier(x)


def _grad_safe_barrier_fwd(x):
    return _grad_safe_barrier(x), None


def _grad_safe_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_grad_safe_barrier.defvjp(_grad_safe_barrier_fwd, _grad_safe_barrier_bwd)


def _scan_stack(cfg, run, blocks, x, positions, *, kind, build_cache,
                mrope_positions=None, mask_offset=0):
    """lax.scan over a uniform stacked block pytree."""

    def body(x, lp):
        # the barrier stops XLA folding downstream f32 upcasts into the
        # remat-saved residual stack (observed: layer inputs stored in BOTH
        # bf16 and f32, ~2x activation memory on deep stacks)
        x = _grad_safe_barrier(x)
        seq_ax = "model" if run.seq_parallel else None
        x = _constrain(x, run, run.batch_axes, seq_ax, None)
        x, aux, kv = block_fullseq(cfg, run, lp, x, positions, kind=kind,
                                   mrope_positions=mrope_positions,
                                   mask_offset=mask_offset)
        return x, (aux, kv if build_cache else 0)

    if run.remat:
        body = jax.checkpoint(body)
    if run.scan_layers:
        x, (auxs, kvs) = jax.lax.scan(body, x, blocks)
        return x, jnp.sum(auxs), (kvs if build_cache else None)
    n = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    auxs, kvs = [], []
    for i in range(n):
        lp = jax.tree_util.tree_map(lambda a: a[i], blocks)
        x, (aux, kv) = body(x, lp)
        auxs.append(aux)
        kvs.append(kv)
    aux = jnp.sum(jnp.stack(auxs))
    if build_cache:
        kvs = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *kvs)
        return x, aux, kvs
    return x, aux, None


def _hybrid_fullseq(cfg, run, params, x, positions, build_cache):
    """Zamba2: mamba stack + shared attention block every k layers."""
    k_every = cfg.hybrid_attn_every
    ssm_caches, attn_caches = [], []

    def shared_fn(sp, x):
        return block_fullseq(cfg, run, sp, x, positions, kind="dense")

    def ssm_fn(lp, x):
        return block_fullseq(cfg, run, lp, x, positions, kind="ssm")

    if run.remat:
        shared_fn = jax.checkpoint(shared_fn)
        ssm_fn = jax.checkpoint(ssm_fn)

    for i in range(cfg.n_layers):
        x = _constrain(x, run, run.batch_axes, None, None)
        if k_every and i % k_every == 0:
            x, _, kv = shared_fn(params["shared"], x)
            if build_cache:
                attn_caches.append(kv)
        lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        x, _, st = ssm_fn(lp, x)
        if build_cache:
            ssm_caches.append(st)
    cache = None
    if build_cache:
        cache = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ssm_caches)
        akv = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *attn_caches)
        cache = {"h": cache["h"], "conv": cache["conv"],
                 "ak": akv["k"], "av": akv["v"]}
    return x, cache


def forward(cfg, params, batch, run=RunCfg()):
    """Full-sequence forward. Returns (logits (B,S,V), aux dict)."""
    tokens = batch["tokens"]
    positions = _positions(batch, tokens)
    x = embed_tokens(cfg, params, tokens)
    aux = jnp.zeros((), jnp.float32)
    mrope = batch.get("mrope_positions")

    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, run, params, batch["frames"])
        x = x + Lyr.sinusoidal_positions(x.shape[1],
                                         cfg.d_model)[None].astype(x.dtype)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x = _constrain(x, run, run.batch_axes, None, None)
            x, a, _ = block_fullseq(cfg, run, lp, x, positions, kind="dec",
                                    enc_out=enc_out)
            aux = aux + a
        x = _constrain(x, run, run.batch_axes, None, None)
    elif cfg.family == "hybrid":
        x, _ = _hybrid_fullseq(cfg, run, params, x, positions, False)
    else:
        if cfg.family == "moe" and cfg.first_dense_layers:
            x, a, _ = _scan_stack(cfg, run, params["dense0"], x, positions,
                                  kind="moe_dense0", build_cache=False,
                                  mrope_positions=mrope)
            aux = aux + a
        x, a, _ = _scan_stack(cfg, run, params["blocks"], x, positions,
                              kind=main_block_kind(cfg), build_cache=False,
                              mrope_positions=mrope)
        aux = aux + a
    return lm_logits(cfg, run, params, x), {"moe_aux": aux}


# ---------------------------------------------------------------------------
# Prefill: forward + cache construction (cache length padded to max_len)
# ---------------------------------------------------------------------------


def _pad_cache_len(kvs, S, max_len, axis):
    if max_len <= S:
        return kvs
    pad = [(0, 0)] * 10

    def p(a, ax):
        cfgp = [(0, 0)] * a.ndim
        cfgp[ax] = (0, max_len - S)
        return jnp.pad(a, cfgp)
    return jax.tree_util.tree_map(lambda a: p(a, axis), kvs)


def prefill(cfg, params, batch, run=RunCfg(), max_len=None):
    """Returns (logits, cache). Cache seq dims padded to ``max_len``."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    positions = _positions(batch, tokens)
    x = embed_tokens(cfg, params, tokens)
    mrope = batch.get("mrope_positions")

    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, run, params, batch["frames"])
        x = x + Lyr.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x = _constrain(x, run, run.batch_axes, None, None)
            x, _, kv = block_fullseq(cfg, run, lp, x, positions, kind="dec",
                                     enc_out=enc_out)
            kvs.append(kv)
        kvs = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *kvs)
        cache = {"k": kvs["k"], "v": kvs["v"], "xk": kvs["xk"],
                 "xv": kvs["xv"]}
        cache = {k: (_pad_cache_len(v, S, max_len, 2)
                     if k in ("k", "v") else v) for k, v in cache.items()}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_fullseq(cfg, run, params, x, positions, True)
        for key in ("ak", "av"):
            cache[key] = _pad_cache_len(cache[key], S, max_len, 2)
    elif cfg.family == "ssm":
        x, _, cache = _scan_stack(cfg, run, params["blocks"], x, positions,
                                  kind="ssm", build_cache=True)
    else:
        caches = []
        if cfg.family == "moe" and cfg.first_dense_layers:
            x, _, kv0 = _scan_stack(cfg, run, params["dense0"], x, positions,
                                    kind="moe_dense0", build_cache=True,
                                    mrope_positions=mrope)
            caches.append(kv0)
        x, _, kv = _scan_stack(cfg, run, params["blocks"], x, positions,
                               kind=main_block_kind(cfg), build_cache=True,
                               mrope_positions=mrope)
        caches.append(kv)
        cache = jax.tree_util.tree_map(
            lambda *a: jnp.concatenate(a, axis=0), *caches) \
            if len(caches) > 1 else caches[0]
        cache = _pad_cache_len(cache, S, max_len, 2)
    return lm_logits(cfg, run, params, x[:, -1:]), cache


# ---------------------------------------------------------------------------
# Decode cache allocation (for dry-run / serving without a prefill pass)
# ---------------------------------------------------------------------------


def cache_struct(cfg, batch, max_len, dtype=None):
    """ShapeDtypeStructs (or zeros via init_cache) for the decode cache."""
    dt = jnp.dtype(dtype or cfg.dtype)
    hd = cfg.resolved_head_dim
    L, B, M = cfg.n_layers, batch, max_len

    def sd(shape, d=dt):
        return jax.ShapeDtypeStruct(shape, d)

    if cfg.family in ("dense", "vlm"):
        return {"k": sd((L, B, M, cfg.n_kv_heads, hd)),
                "v": sd((L, B, M, cfg.n_kv_heads, hd))}
    if cfg.family == "moe":
        if cfg.use_mla:
            return {"ckv": sd((L, B, M, cfg.kv_lora_rank)),
                    "krope": sd((L, B, M, cfg.qk_rope_head_dim))}
        return {"k": sd((L, B, M, cfg.n_kv_heads, hd)),
                "v": sd((L, B, M, cfg.n_kv_heads, hd))}
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    C = cfg.d_inner_ssm + 2 * cfg.ssm_n_groups * N
    if cfg.family == "ssm":
        return {"h": sd((L, B, H, P, N), jnp.float32),
                "conv": sd((L, B, cfg.ssm_conv - 1, C))}
    if cfg.family == "hybrid":
        I = n_shared_attn(cfg)
        return {"h": sd((L, B, H, P, N), jnp.float32),
                "conv": sd((L, B, cfg.ssm_conv - 1, C)),
                "ak": sd((I, B, M, cfg.n_kv_heads, hd)),
                "av": sd((I, B, M, cfg.n_kv_heads, hd))}
    if cfg.is_encoder_decoder:
        return {"k": sd((L, B, M, cfg.n_kv_heads, hd)),
                "v": sd((L, B, M, cfg.n_kv_heads, hd)),
                "xk": sd((L, B, cfg.encoder_seq, cfg.n_kv_heads, hd)),
                "xv": sd((L, B, cfg.encoder_seq, cfg.n_kv_heads, hd))}
    raise ValueError(cfg.family)


def init_cache(cfg, batch, max_len, dtype=None):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  cache_struct(cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(cfg, params, token, cache, cache_len, run=RunCfg(),
                mrope_positions=None):
    """token (B,1) int32; cache per ``cache_struct``; cache_len () int32.

    Returns (logits (B,1,V), new_cache).
    """
    x = embed_tokens(cfg, params, token)
    kind = main_block_kind(cfg)

    if cfg.is_encoder_decoder:
        x = x + jax.lax.dynamic_slice_in_dim(
            Lyr.sinusoidal_positions(cache.get("k").shape[2], cfg.d_model),
            cache_len, 1, axis=0)[None].astype(x.dtype)
        new_layers = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            csl = jax.tree_util.tree_map(lambda a: a[i], cache)
            x, nc = block_decode(cfg, run, lp, x, csl, cache_len, kind="dec")
            new_layers.append(nc)
        new_cache = jax.tree_util.tree_map(lambda *a: jnp.stack(a),
                                           *new_layers)
    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        hs, convs, aks, avs = [], [], [], []
        inv = 0
        for i in range(cfg.n_layers):
            if k_every and i % k_every == 0:
                sp = params["shared"]
                xin = Lyr.rmsnorm(sp["ln1"], x, cfg.norm_eps)
                h, kc, vc = gqa_decode(cfg, run, sp["attn"], xin,
                                       cache["ak"][inv], cache["av"][inv],
                                       cache_len)
                x = x + h
                x = x + Lyr.mlp(cfg, sp["mlp"],
                                Lyr.rmsnorm(sp["ln2"], x, cfg.norm_eps))
                aks.append(kc)
                avs.append(vc)
                inv += 1
            lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            csl = {"h": cache["h"][i], "conv": cache["conv"][i]}
            x, nc = block_decode(cfg, run, lp, x, csl, cache_len, kind="ssm")
            hs.append(nc["h"])
            convs.append(nc["conv"])
        new_cache = {"h": jnp.stack(hs), "conv": jnp.stack(convs),
                     "ak": jnp.stack(aks), "av": jnp.stack(avs)}
    else:
        # uniform stack: scan over (blocks, cache layers). MoE stacks with a
        # leading dense layer run dense0 as a python loop, then scan the
        # uniform remainder.
        n_dense0 = cfg.first_dense_layers if cfg.family == "moe" else 0
        new_cache_parts = []
        if n_dense0:
            c0 = jax.tree_util.tree_map(lambda a: a[:n_dense0], cache)
            for i in range(n_dense0):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["dense0"])
                csl = jax.tree_util.tree_map(lambda a: a[i], c0)
                x, nc = block_decode(cfg, run, lp, x, csl, cache_len,
                                     kind="moe_dense0",
                                     mrope_positions=mrope_positions)
                new_cache_parts.append(
                    jax.tree_util.tree_map(lambda a: a[None], nc))
            cache_main = jax.tree_util.tree_map(lambda a: a[n_dense0:], cache)
        else:
            cache_main = cache

        def scan_body(x, inp):
            lp, csl = inp
            x, nc = block_decode(cfg, run, lp, x, csl, cache_len, kind=kind,
                                 mrope_positions=mrope_positions)
            return x, nc

        if run.scan_layers:
            x, nc_main = jax.lax.scan(scan_body, x,
                                      (params["blocks"], cache_main))
        else:
            ncl = []
            n = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                csl = jax.tree_util.tree_map(lambda a: a[i], cache_main)
                x, nc = scan_body(x, (lp, csl))
                ncl.append(nc)
            nc_main = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ncl)
        new_cache_parts.append(nc_main)
        new_cache = jax.tree_util.tree_map(
            lambda *a: jnp.concatenate(a, axis=0), *new_cache_parts) \
            if len(new_cache_parts) > 1 else new_cache_parts[0]

    return lm_logits(cfg, run, params, x), new_cache


def serve_step(cfg, params, token, cache, cache_len, rng, run=RunCfg(),
               temperature=0.0):
    """decode_step + sampling -> (next_token (B,1), new_cache)."""
    logits, new_cache = decode_step(cfg, params, token, cache, cache_len, run)
    lg = logits[:, -1, :].astype(jnp.float32)
    if temperature and temperature > 0:
        nxt = jax.random.categorical(rng, lg / temperature, axis=-1)
    else:
        nxt = jnp.argmax(lg, axis=-1)
    return nxt[:, None].astype(jnp.int32), new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(cfg, params, batch, run=RunCfg()):
    """Causal LM cross-entropy (labels == -1 ignored) + MoE aux.

    Written shard-wise over the vocab dim: the lse reduction and the
    one-hot pick both reduce over V, so with logits constrained to
    (batch, None, "model") GSPMD lowers them to local reductions + psum
    instead of gathering the (B,S,V) tensor.
    """
    logits, aux = forward(cfg, params, batch, run)
    labels = batch["labels"]
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), cfg.padded_vocab,
                            dtype=lg.dtype)
    onehot = _constrain(onehot, run, run.batch_axes, None, "model")
    picked = jnp.sum(lg * onehot, axis=-1)
    nll = lse - picked
    valid = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
    return loss + run.aux_coef * aux["moe_aux"], {
        "loss": loss, "moe_aux": aux["moe_aux"]}
