"""Pallas TPU kernel: causal GQA flash attention (prefill/train forward).

Standard TPU flash schedule: grid (B, Hq, nq, nk) with the LAST dim the
sequential KV walk ("arbitrary" dimension semantics); the running
(acc, m, l) triple lives in VMEM scratch carried across kv steps, o is
written on the final step. KV blocks index through the GQA map h -> h // G.

Block sizes default (qb=256, kb=512, D<=128-padded): VMEM per step ~
qb*D + kb*D + qb*kb floats ~ 0.8 MB << 16 MB v5e VMEM; both matmuls hit the
MXU at (qb x D) @ (D x kb) and (qb x kb) @ (kb x D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr,
                  *, scale, causal, qb, kb, nk, t_real):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0]                                   # (qb, D)
    k = k_ref[0, 0]                                   # (kb, D)
    v = v_ref[0, 0]                                   # (kb, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    cols = kj * kb + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < t_real                              # padded KV columns
    if causal:
        rows = qi * qb + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = mask & (cols <= rows)
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[:, 0]                              # (qb,)
    l_prev = l_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc[...] = (acc[...] * alpha[:, None]
                + jnp.dot(p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32))
    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new

    @pl.when(kj == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc[...] /
                       jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, q_block=256,
                           kv_block=512, interpret=True):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D). Returns (B, Hq, S, D).

    Head-major layout (transposed by ops.py from the model's (B,S,H,D)).
    """
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    qb, kb = min(q_block, S), min(kv_block, T)
    nq, nk = -(-S // qb), -(-T // kb)
    Sp, Tp = nq * qb, nk * kb
    Dp = -(-D // 128) * 128
    q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, Dp - D)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, Dp - D)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, Dp - D)))

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               qb=qb, kb=kb, nk=nk, t_real=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qb, Dp), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, Dp),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, kb, Dp),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, Dp),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, Dp), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :D]
