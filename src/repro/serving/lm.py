"""TinyJaxLM: the QueryLM interface driven through the REAL JAX engine.

Prompt -> tokens -> chunked sampled decode -> detokenize. With random
weights the text is gibberish (no pretrained weights ship here), so the
paper-reproduction benchmarks use the SyntheticOracleLM for semantics —
this class exists to prove the generator/runtime plumbing runs an actual
LLM end-to-end (and is what you'd swap real weights into).
"""
from __future__ import annotations

from repro.serving.engine import Engine


PROMPT = ("you are a user asking questions about the following document. "
          "do not repeat any of these earlier questions: {masked}. "
          "document: {chunk}. question:")


class TinyJaxLM:
    def __init__(self, engine: Engine, max_new: int = 12):
        self.engine = engine
        self.max_new = max_new
        self._seed = 0

    def generate_query(self, chunk_text, masked, temperature, rng):
        chunk = chunk_text.split("\x00", 1)[-1]
        prompt = PROMPT.format(masked="; ".join(masked[:8]), chunk=chunk)
        self._seed += 1
        return self.engine.generate(prompt, max_new=self.max_new,
                                    temperature=float(temperature),
                                    seed=self._seed)

    def answer(self, query, chunk_text):
        chunk = chunk_text.split("\x00", 1)[-1]
        prompt = f"document: {chunk}. question: {query}. answer:"
        return self.engine.generate(prompt, max_new=self.max_new,
                                    temperature=None, seed=0)
