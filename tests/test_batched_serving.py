"""Batched StorInfer serving: BatchedRuntime hit/miss/mixed batches and
cancellation accounting, MicroBatcher admission, engine batch sessions,
auto_index tier selection, IVF recall measurement."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.embedder import HashEmbedder
from repro.core.index import (FLAT_MAX_ROWS, FlatIndex, IVFIndex,
                              auto_index, ivf_params, select_tier)
from repro.core.runtime import (BatchedRuntime, BatchedRuntimeCfg,
                                StorInferRuntime)
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer
from repro.core.kb import build_kb
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.scheduler import MicroBatcher


@pytest.fixture(scope="module")
def tiny_engine():
    kb = build_kb("squad", n_docs=4)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-1.7b")),
        vocab_size=tok.vocab_size, n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    run = M.RunCfg(attn_impl="naive", remat=False)
    return Engine(cfg, params, tok, run, max_len=96, chunk=4), tok


@pytest.fixture()
def stored(tmp_path):
    emb = HashEmbedder()
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    qs = ["what is the height of aurora bridge?",
          "who founded the meridian institute?",
          "when was the treaty of helsport signed?"]
    rs = ["the height is two hundred meters.",
          "elena marchetti founded it.",
          "it was signed in 1907."]
    store.add_batch(emb.encode(qs), qs, rs)
    store.flush()
    return emb, store, qs, rs


# ---------------------------------------------------------------------------
# BatchedRuntime — search-only batches
# ---------------------------------------------------------------------------


def test_batched_all_hit_search_only(stored):
    emb, store, qs, rs = stored
    rt = BatchedRuntime.from_store(store, emb)
    res = rt.query_batch(qs)
    assert [r.response for r in res] == rs
    assert all(r.hit and r.source == "store" and not r.cancelled
               for r in res)
    assert rt.stats.hits == 3 and rt.stats.misses == 0
    assert rt.stats.llm_cancelled == 0      # no engine -> nothing to cancel


def test_batched_mixed_hit_miss_search_only(stored):
    emb, store, qs, rs = stored
    rt = BatchedRuntime.from_store(store, emb)
    mixed = [qs[0], "zebra xylophone unrelated nonsense", qs[2]]
    res = rt.query_batch(mixed)
    assert [r.hit for r in res] == [True, False, True]
    assert res[1].source == "llm" and res[1].response == ""
    assert rt.stats.queries == 3 and rt.stats.hits == 2
    assert rt.stats.misses == 1 and rt.stats.batches == 1


def test_batched_empty_batch(stored):
    emb, store, qs, rs = stored
    rt = BatchedRuntime.from_store(store, emb)
    assert rt.query_batch([]) == []
    assert rt.stats.queries == 0


def test_batched_matches_sequential_runtime(stored):
    """Same store, same queries: the batched path must agree with the
    sequential reference runtime on every hit decision and response."""
    emb, store, qs, rs = stored
    queries = qs + ["totally novel zebra question"]
    seq = StorInferRuntime(FlatIndex(store.embeddings()), store, emb)
    bat = BatchedRuntime.from_store(store, emb)
    seq_res = [seq.query(q) for q in queries]
    bat_res = bat.query_batch(queries)
    for s, b in zip(seq_res, bat_res):
        assert s.hit == b.hit
        assert s.response == b.response
        assert abs(s.score - b.score) < 1e-5


# ---------------------------------------------------------------------------
# BatchedRuntime — with engine: cancellation accounting + write-back
# ---------------------------------------------------------------------------


class _SlowEmbedder(HashEmbedder):
    """Delays encode so the batched decode reliably starts before the
    search returns — exercising the mid-flight cancellation path."""

    def encode(self, texts):
        time.sleep(0.1)
        return super().encode(texts)


def test_batched_engine_hits_cancel_misses_decode(tiny_engine, stored):
    eng, tok = tiny_engine
    emb, store, qs, rs = stored
    rt = BatchedRuntime.from_store(store, _SlowEmbedder(), engine=eng)
    mixed = [qs[0], "completely unrelated zebra xylophone", qs[1]]
    res = rt.query_batch(mixed, max_new=8)
    assert res[0].hit and res[0].response == rs[0]
    assert res[2].hit and res[2].response == rs[1]
    assert not res[1].hit and res[1].source == "llm"
    assert res[1].response != "" and not res[1].cancelled
    # cancellation accounting invariants: llm_cancelled counts exactly the
    # results flagged cancelled, and only hits can be hit-cancelled
    assert rt.stats.llm_cancelled == sum(r.cancelled for r in res)
    assert all(r.hit for r in res if r.cancelled)
    assert rt.stats.hits == 2 and rt.stats.misses == 1


def test_batched_add_misses_writeback_and_rebuild(tiny_engine, stored):
    eng, tok = tiny_engine
    emb, store, qs, rs = stored
    rt = BatchedRuntime.from_store(
        store, emb, engine=eng,
        cfg=BatchedRuntimeCfg(add_misses=True, rebuild_every=1))
    novel = "a brand new zebra question never stored"
    res = rt.query_batch([novel], max_new=8)
    assert not res[0].hit
    assert rt.stats.writebacks == 1 and rt.stats.index_rebuilds == 1
    assert store.count == 4
    # the rebuilt index now serves the written-back pair as a hit
    res2 = rt.query_batch([novel], max_new=8)
    assert res2[0].hit and res2[0].response == res[0].response
    assert rt.stats.hits == 1 and rt.stats.misses == 1


# ---------------------------------------------------------------------------
# MicroBatcher admission queue
# ---------------------------------------------------------------------------


def test_microbatcher_batches_and_resolves():
    seen_batches = []

    def process(subs):
        seen_batches.append(len(subs))
        return [s.text.upper() for s in subs]

    with MicroBatcher(process, max_batch=4, max_wait_s=0.05) as mb:
        futs = [mb.submit(f"q{i}") for i in range(10)]
        results = [f.result(timeout=10) for f in futs]
    assert results == [f"Q{i}" for i in range(10)]
    assert mb.stats.items == 10
    assert max(seen_batches) <= 4
    assert mb.stats.batches == len(seen_batches)


def test_microbatcher_error_fails_batch_only():
    def process(subs):
        if any("bad" in s.text for s in subs):
            raise ValueError("poison")
        return [s.text for s in subs]

    mb = MicroBatcher(process, max_batch=1, max_wait_s=0.0).start()
    try:
        bad = mb.submit("bad query")
        with pytest.raises(ValueError):
            bad.result(timeout=10)
        ok = mb.submit("fine")          # worker survived the poison batch
        assert ok.result(timeout=10) == "fine"
    finally:
        mb.stop()


def test_microbatcher_exception_errors_all_futures_in_batch():
    """process_batch raising must FAIL every future in that batch — not
    leave callers hanging on result() forever."""
    def process(subs):
        raise RuntimeError("boom")

    mb = MicroBatcher(process, max_batch=8, max_wait_s=0.05).start()
    try:
        futs = [mb.submit(f"q{i}") for i in range(5)]
        for f in futs:
            with pytest.raises(RuntimeError, match="boom"):
                f.result(timeout=10)       # resolves (with the error)
    finally:
        mb.stop()


def test_microbatcher_wrong_result_count_errors_not_hangs():
    mb = MicroBatcher(lambda subs: ["only one"], max_batch=4,
                      max_wait_s=0.05).start()
    try:
        futs = [mb.submit(f"q{i}") for i in range(3)]
        for f in futs:
            with pytest.raises(RuntimeError, match="returned 1 results"):
                f.result(timeout=10)
    finally:
        mb.stop()


def test_microbatcher_submit_after_stop_raises():
    mb = MicroBatcher(lambda subs: [s.text for s in subs]).start()
    mb.stop()
    with pytest.raises(RuntimeError, match="not running"):
        mb.submit("too late")
    # restartable: start() brings up a fresh worker
    with mb:
        assert mb.submit("again").result(timeout=10) == "again"
    with pytest.raises(RuntimeError):
        mb.submit("closed again")


def test_microbatcher_drain_on_shutdown():
    """stop(drain=True) processes everything already queued; with
    drain=False the queued futures are cancelled instead."""
    gate = threading.Event()

    def process(subs):
        gate.wait(timeout=10)
        return [s.text for s in subs]

    mb = MicroBatcher(process, max_batch=1, max_wait_s=0.0).start()
    futs = [mb.submit(f"q{i}") for i in range(4)]
    gate.set()
    mb.stop(drain=True)
    assert [f.result(timeout=10) for f in futs] == [f"q{i}"
                                                    for i in range(4)]

    gate.clear()
    mb2 = MicroBatcher(process, max_batch=1, max_wait_s=0.0).start()
    first = mb2.submit("in flight")        # worker blocks on the gate
    time.sleep(0.05)
    queued = [mb2.submit(f"w{i}") for i in range(3)]
    # release the in-flight batch only after stop() has cancelled the
    # queued ones (otherwise the worker could race in and process them)
    threading.Timer(0.2, gate.set).start()
    mb2.stop(drain=False)
    assert first.result(timeout=10) == "in flight"
    assert all(f.cancelled() for f in queued)


def test_runtime_submit_end_to_end(stored):
    emb, store, qs, rs = stored
    with BatchedRuntime.from_store(
            store, emb,
            cfg=BatchedRuntimeCfg(max_batch=8, max_wait_s=0.05)) as rt:
        futs = [rt.submit(q) for q in qs + ["novel zebra"]]
        res = [f.result(timeout=30) for f in futs]
    assert [r.hit for r in res] == [True, True, True, False]
    assert [r.response for r in res[:3]] == rs
    assert rt.stats.queries == 4


# ---------------------------------------------------------------------------
# Engine batch session API
# ---------------------------------------------------------------------------


def test_generate_batch_matches_single(tiny_engine):
    eng, tok = tiny_engine
    # mixed prompt lengths exercise the wave-gated admission
    prompts = ["hello world what is", "tell me", "hello world what was"]
    batch = eng.generate_batch(prompts, max_new=6)
    single = [eng.generate(p, max_new=6) for p in prompts]
    assert batch == single


def test_batch_session_cancel_is_per_request(tiny_engine):
    eng, tok = tiny_engine
    s = eng.start_batch_session(["question one x", "question two y"],
                                max_new=16)
    s.cancel(0)
    s.run()
    res = s.results()
    assert res[0].cancelled
    assert not res[1].cancelled and len(res[1].out_ids) > 0


# ---------------------------------------------------------------------------
# auto_index tier selection + IVF recall
# ---------------------------------------------------------------------------


def test_select_tier_boundaries():
    assert select_tier(1) == "flat"
    assert select_tier(FLAT_MAX_ROWS) == "flat"
    assert select_tier(FLAT_MAX_ROWS + 1) == "ivf"
    # sharding needs both a multi-device axis and enough rows
    assert select_tier(4 * FLAT_MAX_ROWS, mesh_axis_size=8) == "sharded"
    assert select_tier(4 * FLAT_MAX_ROWS - 1, mesh_axis_size=8) == "ivf"
    assert select_tier(4 * FLAT_MAX_ROWS, mesh_axis_size=1) == "ivf"
    assert select_tier(100, mesh_axis_size=8) == "flat"
    with pytest.raises(ValueError):
        select_tier(0)


def test_auto_index_builds_right_types(tmp_path):
    rng = np.random.default_rng(0)
    small = rng.normal(size=(50, 32)).astype(np.float32)
    assert isinstance(auto_index(small), FlatIndex)
    big = rng.normal(size=(200, 32)).astype(np.float32)
    idx = auto_index(big, flat_max_rows=64)
    assert isinstance(idx, IVFIndex)
    n_lists, nprobe = ivf_params(200)
    assert idx.n_lists == n_lists and idx.nprobe == nprobe
    # factory accepts a store too
    emb = HashEmbedder(dim=32)
    store = PrecomputedStore(tmp_path / "s", dim=32)
    store.add_batch(small[:10], [f"q{i}" for i in range(10)],
                    [f"r{i}" for i in range(10)])
    store.flush()
    flat = auto_index(store)
    assert isinstance(flat, FlatIndex) and len(flat) == 10


def test_ivf_recall_vs_flat_method():
    rng = np.random.default_rng(3)
    centers = rng.normal(size=(16, 48)).astype(np.float32)
    x = (centers[rng.integers(0, 16, 1500)]
         + 0.1 * rng.normal(size=(1500, 48)).astype(np.float32))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    q = x[rng.choice(1500, 40)]
    ivf = IVFIndex(x, n_lists=16, nprobe=6)
    r = ivf.recall_vs_flat(q, k=10)
    assert 0.8 < r <= 1.0, r
    # probing every list makes IVF exhaustive -> perfect recall
    full = IVFIndex(x, n_lists=16, nprobe=16)
    assert full.recall_vs_flat(q, k=10) == 1.0
