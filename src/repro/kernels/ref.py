"""Oracles for every kernel in this package: pure-jnp allclose targets for
the attention kernels, and NUMPY bit-for-bit targets for the MIPS top-k
pair (the int8 kernel's contract is exact, so its reference avoids jax
entirely)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mips_topk_ref(q, x, k):
    """q: (Q,D); x: (N,D) -> (vals (Q,k), idx (Q,k)) exact MIPS top-k."""
    s = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    return jax.lax.top_k(s, k)


def topk_by_value_ref(s, k):
    """Numpy top-k along the last axis ordered by (value desc, index asc) —
    the exact tie-break contract of ``tile_topk`` and both MIPS kernels."""
    s = np.asarray(s)
    order = np.argsort(-s, axis=-1, kind="stable")[..., :k]
    return np.take_along_axis(s, order, axis=-1), order.astype(np.int32)


def mips_topk_int8_ref(q, q_scale, x, x_scale, k):
    """Bit-for-bit reference for the int8 kernel: exact int32 accumulation,
    then the SAME f32 dequant multiply order the kernel uses
    (acc -> f32, * q_scale, * x_scale) and the same tie-break."""
    q = np.asarray(q, np.int32)
    x = np.asarray(x, np.int32)
    s = (q @ x.T).astype(np.float32)
    s = s * np.asarray(q_scale, np.float32)[:, None]
    s = s * np.asarray(x_scale, np.float32)[None, :]
    return topk_by_value_ref(s, k)


def attention_ref(q, k, v, *, causal=True):
    """Head-major GQA attention oracle. q: (B,Hq,S,D); k,v: (B,Hkv,T,D)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    s = s * (D ** -0.5)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, S, D)


def decode_attention_ref(q, k, v, lengths):
    """q: (B,Hq,D); k,v: (B,T,Hkv,D); attend [0, lengths[b]] inclusive."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    s = s * (D ** -0.5)
    mask = jnp.arange(T)[None, :] <= lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(jnp.float32),
                   v.astype(jnp.float32))
    return o.reshape(B, Hq, D)
