"""StorInfer core: precomputed query-response storage for LLM inference.

Subsystems (paper section in parens):
  kb         — knowledge bases + user-query distributions (§4 datasets)
  tokenizer  — deterministic text tokenizer (token budgets, tiny LMs)
  embedder   — query embedding (hash n-gram SRP + MiniLM-class JAX encoder)
  store      — disk-backed precomputed-pair store (memmap shards, §3.3)
  index      — MIPS indexes: flat / IVF / mesh-sharded (§2 vector search)
  generator  — deduplicated query generation: adaptive query masking +
               adaptive sampling (§3.2; the sequential reference loop)
  precompute — batched, resumable offline build pipeline: wave generation,
               one embed batch + incremental-index dedup per wave,
               checkpointed into the store manifest (paper-scale §3.2/3.3)
  runtime    — parallel search + cancellable LLM inference (§3.4, Fig 2);
               BatchedRuntime batches admission/search/decode for serving
  metrics    — Unigram F1 / ROUGE-L / BERTScore-proxy (§4)
  latency    — analytic latency models for the paper's H100 point + v5e
"""
