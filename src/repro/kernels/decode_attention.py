"""Pallas TPU kernel: flash-decoding (split-KV single-token attention).

One new token attends a long KV cache: the cache splits into ``n_splits``
chunks along T; grid (B, n_splits) computes a partial (o, m, l) per chunk
(all heads at once — the (Hq x D) @ (D x Tc) score matmul feeds the MXU),
and the host-side combine (ops.py) does the max-rescale merge. This is the
single-chip analogue of the shard_map seq-sharded decode in
repro.distributed.decode_attn (splits -> devices).

The per-request valid length arrives as a (B, 1) i32 input (SMEM-prefetch
scalar on real TPUs; plain input block in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   *, scale, tc, G):
    s_id = pl.program_id(1)
    q = q_ref[0]                                       # (Hq, D)
    k = k_ref[0]                                       # (tc, Hkv, D)
    v = v_ref[0]                                       # (tc, Hkv, D)
    Hq, D = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(Hkv, G, D)
    s = jnp.einsum("kgd,tkd->kgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    pos = s_id * tc + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos <= len_ref[0, 0], s, NEG)
    m = jnp.max(s, axis=2)                             # (Hkv, G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=2)
    o = jnp.einsum("kgt,tkd->kgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.reshape(Hq, D).astype(o_ref.dtype)
    m_ref[0, 0] = m.reshape(Hq)
    l_ref[0, 0] = l.reshape(Hq)


def decode_attention_pallas(q, k, v, lengths, *, n_splits=8, interpret=True):
    """q: (B, Hq, D); k, v: (B, T, Hkv, D); lengths: (B,) i32 — attend
    positions [0, lengths]. Returns per-split partials
    (o (B, ns, Hq, D) f32, m (B, ns, Hq) f32, l (B, ns, Hq) f32)."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    ns = n_splits
    tc = -(-T // ns)
    Tp = ns * tc
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    Dp = -(-D // 128) * 128
    if Dp != D:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Dp - D)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, Dp - D)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, Dp - D)))

    kernel = functools.partial(_decode_kernel, scale=D ** -0.5, tc=tc, G=G)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, s: (b, 0)),          # lengths
            pl.BlockSpec((1, Hq, Dp), lambda b, s: (b, 0, 0)),  # q resident
            pl.BlockSpec((1, tc, Hkv, Dp), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, tc, Hkv, Dp), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Hq, Dp), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, Hq), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, 1, Hq), lambda b, s: (b, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, ns, Hq, Dp), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, Hq), jnp.float32),
            jax.ShapeDtypeStruct((B, ns, Hq), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.reshape(B, 1).astype(jnp.int32), q, k, v)
    return o[..., :D], m, l


def combine_splits(o, m, l):
    """(B,ns,Hq,D),(B,ns,Hq),(B,ns,Hq) -> (B,Hq,D) flash merge."""
    m_g = jnp.max(m, axis=1, keepdims=True)
    corr = jnp.exp(m - m_g)
    l_g = jnp.sum(l * corr, axis=1)
    o_g = jnp.sum(o * corr[..., None], axis=1)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]
