"""Train step: loss -> grads (with microbatch accumulation) -> AdamW.

``make_train_step(cfg, run, ocfg, accum)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with sharded in/out. Gradient accumulation scans over ``accum``
microbatches (activation memory / accum) accumulating f32 grads sharded like
the params — the standard way the assigned global batches (1M tokens) fit
16 GB/chip.

Optional int8 gradient compression (error feedback) is applied between
accumulation and the optimizer — see ``repro.training.compression``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.training import optimizer as O


def _split_batch(batch, accum):
    """(B, ...) -> (accum, B/accum, ...) on every leading-batch leaf."""

    def split(x, batch_dim):
        B = x.shape[batch_dim]
        assert B % accum == 0, (B, accum)
        per = B // accum
        moved = jnp.moveaxis(x, batch_dim, 0)
        moved = moved.reshape((accum, per) + moved.shape[1:])
        return jnp.moveaxis(moved, 1, batch_dim + 1)

    out = {}
    for k, v in batch.items():
        out[k] = split(v, 1 if k == "mrope_positions" else 0)
    return out


def make_grad_fn(cfg, run):
    def loss_fn(params, batch):
        loss, metrics = M.lm_loss(cfg, params, batch, run)
        return loss, metrics
    return jax.value_and_grad(loss_fn, has_aux=True)


def make_train_step(cfg, run, ocfg=O.AdamWCfg(), accum=1, compress=None):
    grad_fn = make_grad_fn(cfg, run)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        else:
            micro = _split_batch(batch, accum)

            def body(acc, mb):
                (l, mt), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, l

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, g0, micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
            metrics = {"loss": loss, "moe_aux": jnp.zeros((), jnp.float32)}
        if compress is not None:
            grads, opt_state = compress(grads, opt_state)
        new_params, new_opt, om = O.update(ocfg, grads, opt_state, params)
        metrics = dict(metrics, **om, loss=loss)
        return new_params, new_opt, metrics

    return train_step
