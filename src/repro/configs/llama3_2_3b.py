"""Llama-3.2-3B dense [hf:meta-llama/Llama-3.2-3B; unverified].

28L, d_model 3072, 24 heads GQA kv=8, d_ff 8192, vocab 128256, RoPE theta 5e5.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    tie_embeddings=True,
    norm_eps=1e-5,
))
