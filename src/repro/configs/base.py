"""Model/shape/mesh configuration system.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves it. ``reduced()`` produces the
CPU-smoke-test variant of any config (same family / same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes assigned to the LM pool (seq_len x global_batch).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- norm / attention details -----------------------------------------
    norm_eps: float = 1e-5
    qk_norm: bool = False  # qwen3
    attn_bias: bool = False  # qwen2.5 QKV bias
    mlp_act: str = "silu"
    gated_mlp: bool = True  # SwiGLU-style
    rope_theta: float = 1e4
    rope_kind: str = "standard"  # standard | mrope | none
    mrope_sections: Tuple[int, ...] = ()
    tie_embeddings: bool = False
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0  # width of leading dense layers in MoE stacks
    moe_capacity_factor: float = 1.25
    # --- MLA (deepseek) -----------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0  # 0 -> no q compression
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / zamba2) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2): shared attention block every k mamba layers -------
    hybrid_attn_every: int = 0
    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # audio frames after the (stubbed) conv frontend
    # --- modality frontend stub ----------------------------------------------
    frontend: str = "none"  # none | audio | vision
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    # --- remat / scan ----------------------------------------------------------
    remat: bool = True
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 64 (Megatron-style) so the logits
        dim shards on any model axis up to 64-way. Non-divisible vocabs
        (whisper 51865, mamba2 50280) otherwise force GSPMD to replicate the
        full (B,S,V) logits per device (observed 217 GB). Padded columns are
        masked to -inf in lm_logits."""
        return -(-self.vocab_size // 64) * 64

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (matches init_model within ties/bias noise)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        hd = self.resolved_head_dim

        def attn_params() -> int:
            if self.use_mla:
                p = d * (self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim))
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            p += self.n_heads * hd * d
            return p

        def mlp_params(ff: int) -> int:
            return d * ff * (3 if self.gated_mlp else 2)

        def ssm_params() -> int:
            di, ns, ng = self.d_inner_ssm, self.ssm_state, self.ssm_n_groups
            p = d * (2 * di + 2 * ng * ns + self.n_ssm_heads)  # in_proj
            p += di * d  # out_proj
            p += (di + 2 * ng * ns) * self.ssm_conv
            p += 3 * self.n_ssm_heads  # A, dt_bias, D
            return p

        if self.family == "dense" or self.family == "vlm":
            total += self.n_layers * (attn_params() + mlp_params(self.d_ff))
        elif self.family == "moe":
            n_moe = self.n_layers - self.first_dense_layers
            per_moe = attn_params()
            per_moe += self.n_experts * 3 * d * self.d_ff_expert
            per_moe += self.n_shared_experts * 3 * d * self.d_ff_expert
            per_moe += d * self.n_experts  # router
            total += n_moe * per_moe
            total += self.first_dense_layers * (
                attn_params() + mlp_params(self.d_ff_dense or self.d_ff))
        elif self.family == "ssm":
            total += self.n_layers * ssm_params()
        elif self.family == "hybrid":
            total += self.n_layers * ssm_params()
            total += attn_params() + mlp_params(self.d_ff)  # one shared block
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff))
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff))
            total += enc + dec
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        n_moe = self.n_layers - self.first_dense_layers
        inactive = n_moe * (self.n_experts - self.experts_per_tok) * 3 * d * self.d_ff_expert
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # importing the modules populates the registry via register()
    from repro.configs import (  # noqa: F401
        deepseek_v2_lite_16b, grok_1_314b, whisper_base, llama3_2_3b,
        starcoder2_7b, qwen3_1_7b, qwen2_5_32b, zamba2_1_2b, qwen2_vl_72b,
        mamba2_130m, storinfer_paper)


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same family & code paths, tiny dims.
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 3 if cfg.family != "hybrid" else 5),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
    )
    if cfg.family == "moe":
        # capacity factor high enough that no token drops at smoke scale —
        # capacity dropping is count-dependent and would (legitimately) break
        # prefill-vs-forward exactness checks.
        kw.update(n_experts=min(cfg.n_experts, 8), experts_per_tok=2,
                  d_ff_expert=64, d_ff_dense=128,
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  moe_capacity_factor=64.0)
    if cfg.use_mla:
        kw.update(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        kw.update(hybrid_attn_every=2)
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=2, encoder_seq=32)
    if cfg.rope_kind == "mrope":
        kw.update(mrope_sections=(4, 2, 2))
    return dataclasses.replace(cfg, **kw)
