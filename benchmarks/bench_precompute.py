"""Offline precompute pipeline benchmark: the paper-scale store build.

Four checks, emitted as one BENCH_precompute.json point:

  1. **speedup** — batched `PrecomputePipeline` (wave 32) vs the sequential
     `QueryGenerator.generate` reference on the same KB/target/seed.
     Acceptance floor: >= 3x pairs/sec.
  2. **scale** — a large deduplicated store build through `StorInfer.build`
     (>= 100K rows in full mode; scaled down under --smoke), reporting
     pairs/sec, discard rate, and the storage split.
  3. **index cache** — `make_index("auto", store, cache_dir=store.root)`
     twice: the first call fits + persists IVF k-means, the second must
     LOAD it (no k-means — asserted, not just timed) and return identical
     search results.
  4. **resume** — the build is killed mid-flight and resumed; the resumed
     store must be byte-identical (text, offsets, every embedding shard)
     to an uninterrupted run.

  PYTHONPATH=src python benchmarks/bench_precompute.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np

from benchmarks.common import out_write
from repro.api import StorInfer, SystemCfg, make_embedder, make_index, \
    make_pipeline
from repro.core.generator import (GenCfg, QueryGenerator, SyntheticOracleLM,
                                  chunk_key)
from repro.core.kb import build_kb
from repro.core.precompute import BuildKilled, PrecomputeCfg


def kb_env(n_docs: int, seed: int = 0):
    from repro.core.tokenizer import Tokenizer
    kb = build_kb("squad", seed=seed, n_docs=n_docs)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    return kb, tok, chunks


def bench_speedup(n_pairs: int, wave: int, n_docs: int = 60):
    kb, tok, chunks = kb_env(n_docs=n_docs)
    emb = make_embedder("hash")

    t0 = time.perf_counter()
    gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True))
    sq, _, _, sstats = gen.generate(chunks, n_pairs, seed=0)
    seq_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    pipe = make_pipeline(SystemCfg(precompute=PrecomputeCfg(wave=wave)),
                         SyntheticOracleLM(kb), tok)
    bq, _, be, bstats = pipe.run(chunks, n_pairs, seed=0)
    bat_s = time.perf_counter() - t0

    assert len(sq) == len(bq) == n_pairs, (len(sq), len(bq))
    sims = be @ be.T - np.eye(len(be))
    assert sims.max() < 0.99, "pipeline accepted a near-duplicate"
    return {
        "n_pairs": n_pairs, "wave": wave,
        "sequential": {"seconds": seq_s, "pairs_per_sec": n_pairs / seq_s,
                       "discarded": sstats.discarded},
        "batched": {"seconds": bat_s, "pairs_per_sec": n_pairs / bat_s,
                    "discarded": bstats.discarded},
        "speedup": seq_s / bat_s,
    }


def bench_scale(root: Path, n_rows: int, wave: int, n_docs: int,
                background: bool):
    kb, tok, chunks = kb_env(n_docs=n_docs)
    # index="none": the serving index is fit (and timed) separately by
    # bench_index_cache, which asserts the first fit does NOT hit a cache
    cfg = SystemCfg(index="none", precompute=PrecomputeCfg(
        wave=wave, background_recluster=background))
    t0 = time.perf_counter()
    si = StorInfer.build(kb, cfg, root, n_pairs=n_rows, tokenizer=tok,
                         seed=0)
    build_s = time.perf_counter() - t0
    stats = si.build_stats
    sb = si.store.storage_bytes()
    out = {
        "rows": si.store.count, "seconds": build_s,
        "pairs_per_sec": stats.generated / build_s,
        "discarded": stats.discarded,
        "dedup_index_mode": stats.index_mode,
        "store_mb": sb["total_bytes"] / 1e6,
        "embeddings_mb": sb["index_bytes"] / 1e6,
        "metadata_mb": sb["metadata_bytes"] / 1e6,
    }
    return si.store, out


def bench_index_cache(store, flat_max_rows: int):
    t0 = time.perf_counter()
    built = make_index("auto", store, cache_dir=store.root,
                       flat_max_rows=flat_max_rows)
    build_s = time.perf_counter() - t0
    assert built.loaded_from is None, "first build unexpectedly hit a cache"

    t0 = time.perf_counter()
    loaded = make_index("auto", store, cache_dir=store.root,
                        flat_max_rows=flat_max_rows)
    load_s = time.perf_counter() - t0
    assert loaded.loaded_from is not None, \
        "reopen re-ran k-means instead of loading the persisted index"
    q = np.asarray(store.embeddings()[:16], np.float32)
    vb, ib = built.search(q, 5)
    vl, il = loaded.search(q, 5)
    assert np.allclose(vb, vl) and (ib == il).all(), \
        "cached index disagrees with the fresh build"
    return {"build_seconds": build_s, "load_seconds": load_s,
            "load_speedup": build_s / max(load_s, 1e-9),
            "n_lists": built.n_lists}


def bench_resume(td: Path, n_rows: int, wave: int):
    kb, tok, chunks = kb_env(n_docs=20)
    cfg = SystemCfg(index="none", shard_rows=256,
                    precompute=PrecomputeCfg(wave=wave,
                                             checkpoint_every=4))

    A, B = td / "uninterrupted", td / "resumed"
    StorInfer.build(kb, cfg, A, n_pairs=n_rows, tokenizer=tok,
                    seed=5).close()

    try:
        # the kill: StorInfer.build aborts the store handle (buffers reach
        # disk, nothing past the last checkpoint commits) and re-raises
        StorInfer.build(kb, cfg, B, n_pairs=n_rows, tokenizer=tok, seed=5,
                        _kill_after_waves=(n_rows // wave) // 2 + 1)
    except BuildKilled:
        pass
    si = StorInfer.build(kb, cfg, B, n_pairs=n_rows, tokenizer=tok, seed=5)
    stats = si.build_stats
    si.close()

    files = ["text.jsonl", "offsets.npy"] + sorted(
        p.name for p in A.glob("emb_*.npy"))
    identical = all((A / f).read_bytes() == (B / f).read_bytes()
                    for f in files)
    return {"rows": n_rows, "resumed_from": stats.resumed_rows,
            "files_compared": len(files), "identical": identical}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small targets for CI")
    ap.add_argument("--rows", type=int, default=None,
                    help="scale-build row target (default: 100000, or 4000 "
                         "under --smoke)")
    ap.add_argument("--wave", type=int, default=32)
    ap.add_argument("--background-recluster", action="store_true",
                    help="thread the dedup IVF refits during the scale "
                         "build")
    args = ap.parse_args(argv)

    speed_pairs = 1500 if args.smoke else 4000
    speed_docs = 60 if args.smoke else 120
    scale_rows = args.rows or (4000 if args.smoke else 100_000)
    scale_docs = 60 if args.smoke else 500
    resume_rows = 200 if args.smoke else 800
    # keep the cache check meaningful at smoke scale: force the IVF tier
    flat_max = min(32768, max(64, scale_rows // 4))

    print(f"[1/4] speedup: {speed_pairs} pairs, wave {args.wave} ...")
    bench_speedup(200, args.wave)        # warm BLAS/allocators untimed
    speed = bench_speedup(speed_pairs, args.wave, n_docs=speed_docs)
    print(f"  sequential {speed['sequential']['pairs_per_sec']:8.0f} "
          f"pairs/s   batched {speed['batched']['pairs_per_sec']:8.0f} "
          f"pairs/s   speedup {speed['speedup']:.1f}x (floor 3x)")

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        print(f"[2/4] scale build: {scale_rows} rows ...")
        store, scale = bench_scale(td / "scale", scale_rows, args.wave,
                                   scale_docs, args.background_recluster)
        print(f"  {scale['rows']} rows in {scale['seconds']:.1f}s "
              f"({scale['pairs_per_sec']:.0f} pairs/s, "
              f"{scale['discarded']} discarded, "
              f"dedup={scale['dedup_index_mode']}), "
              f"store {scale['store_mb']:.1f} MB")

        print("[3/4] index persistence: fit, persist, reload ...")
        cache = bench_index_cache(store, flat_max)
        store.close()
        print(f"  k-means fit {cache['build_seconds']:.2f}s -> cache load "
              f"{cache['load_seconds']:.2f}s "
              f"({cache['load_speedup']:.1f}x, {cache['n_lists']} lists)")

        print(f"[4/4] kill + resume identity: {resume_rows} rows ...")
        resume = bench_resume(td, resume_rows, 8)
        print(f"  resumed from row {resume['resumed_from']}; "
              f"{resume['files_compared']} files byte-identical: "
              f"{resume['identical']}")

    payload = {"speedup": speed, "scale": scale, "index_cache": cache,
               "resume": resume, "smoke": bool(args.smoke)}
    out_write("BENCH_precompute", payload, root_name="BENCH_precompute")

    ok = True
    if speed["speedup"] < 3.0:
        print("WARNING: batched pipeline below the 3x acceptance floor",
              file=sys.stderr)
        ok = False
    if not resume["identical"]:
        print("WARNING: resumed store differs from uninterrupted build",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
