"""Grok-1 314B MoE [hf:xai-org/grok-1; unverified].

64L, d_model 6144, 48 heads (GQA kv=8), 8 experts top-2, expert d_ff 32768,
vocab 131072. Experts (8) are not divisible by the 16-way model axis, so the
sharding rule uses FSDP expert weights + TP d_ff (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    experts_per_tok=2,
    n_shared_experts=0,
    d_ff_expert=32768,
    first_dense_layers=0,
    rope_theta=1e4,
    norm_eps=1e-5,
))
