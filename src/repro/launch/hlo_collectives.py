"""Collective-traffic extraction from partitioned (post-SPMD) HLO text.

After SPMD partitioning, shapes in the HLO module are PER-DEVICE, so summing
collective result sizes gives per-device traffic directly. Per-op traffic
model (bytes crossing a device's links):

  all-gather          result bytes x (n-1)/n  ~ result
  all-to-all          result bytes x (n-1)/n  ~ result
  collective-permute  result bytes
  reduce-scatter      operand bytes ~ result x group_size
  all-reduce          2 x result bytes        (ring RS+AG equivalence)

Caveat (measured, see launch/costs.py): collectives inside while-loop bodies
appear once in the text regardless of trip count — the dry-run therefore
parses UNROLLED depth-1/depth-2 probe compiles and extrapolates linearly in
layer count; this module flags any collective found inside a non-entry
computation so undercounting cannot pass silently.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "u64": 8, "s64": 8, "u32": 4, "s32": 4,
               "u16": 2, "s16": 2, "u8": 1, "s8": 1, "pred": 1}

COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute")

_LINE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")
_SHAPE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|u64|s64|u32|s32|"
                    r"u16|s16|u8|s8|pred)\[([0-9,]*)\]")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1 = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic (bytes) + op counts from HLO text."""
    per_op = Counter()
    bytes_per_op = Counter()
    in_entry = False
    loop_flagged = 0
    current_comp_entry = False

    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            current_comp_entry = True
        elif ls.startswith("%") and ls.endswith("{"):
            current_comp_entry = False
        m = _LINE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("ty"))
        if op == "all-reduce":
            traffic = 2 * b
        elif op == "reduce-scatter":
            traffic = b * _group_size(line)
        else:
            traffic = b
        per_op[op] += 1
        bytes_per_op[op] += traffic
        if not current_comp_entry:
            loop_flagged += 1

    return {
        "counts": dict(per_op),
        "bytes": dict(bytes_per_op),
        "total_bytes": float(sum(bytes_per_op.values())),
        "non_entry_collectives": loop_flagged,
    }
