"""Disk-backed precomputed query-response store (§3.3).

Layout on disk (root/):
  manifest.json          — dim, dtype, count, shard list, storage split
  emb_XXXX.npy           — embedding shards, (rows, dim) float16 memmap
  text.jsonl             — one {"q": query, "r": response} per row
  offsets.npy            — byte offset of each row in text.jsonl

Embeddings are the "index tier" (paper: 810 MB DiskANN index for 150K),
responses the "metadata tier" (paper: 20 MB); ``storage_bytes()`` reports
the same split for Fig 4 / §4. Appends flush shard-at-a-time; ``open_``
memory-maps the shards so a store larger than RAM still serves (the
storage-as-memory-tier premise of the paper, adapted: host RAM/NVMe is the
backing tier, device HBM the scan tier).
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

import numpy as np

SHARD_ROWS = 32768


class PrecomputedStore:
    def __init__(self, root, dim: int, emb_dtype="float16"):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.dim = dim
        self.emb_dtype = np.dtype(emb_dtype)
        self.count = 0
        self.shards: List[dict] = []
        self._text_f = open(self.root / "text.jsonl", "a+", encoding="utf-8")
        self._offsets: List[int] = []
        self._pending_embs: List[np.ndarray] = []
        self._pending_rows = 0
        # one shared file handle: seek+read / seek+write must be atomic
        self._lock = threading.Lock()

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        """Flush pending rows + manifest and release the text file handle.

        Idempotent; the store is unusable for reads/writes afterwards.
        """
        if self._text_f is not None and not self._text_f.closed:
            self.flush()
            self._text_f.close()

    @property
    def closed(self) -> bool:
        return self._text_f is None or self._text_f.closed

    def __enter__(self) -> "PrecomputedStore":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- write path ---------------------------------------------------------
    def add_batch(self, embs: np.ndarray, queries: Sequence[str],
                  responses: Sequence[str]):
        assert embs.shape == (len(queries), self.dim)
        with self._lock:
            self._text_f.seek(0, 2)
            for q, r in zip(queries, responses):
                self._offsets.append(self._text_f.tell())
                self._text_f.write(json.dumps({"q": q, "r": r}) + "\n")
            self._pending_embs.append(embs.astype(self.emb_dtype))
            self._pending_rows += len(queries)
            self.count += len(queries)
            while self._pending_rows >= SHARD_ROWS:
                self._flush_shard(SHARD_ROWS)

    def _flush_shard(self, rows):
        buf = np.concatenate(self._pending_embs, axis=0)
        shard, rest = buf[:rows], buf[rows:]
        self._pending_embs = [rest] if len(rest) else []
        self._pending_rows = len(rest)
        name = f"emb_{len(self.shards):04d}.npy"
        np.save(self.root / name, shard)
        self.shards.append({"file": name, "rows": int(shard.shape[0])})

    def flush(self):
        with self._lock:
            if self._pending_rows:
                self._flush_shard(self._pending_rows)
            self._text_f.flush()
            np.save(self.root / "offsets.npy",
                    np.asarray(self._offsets, np.int64))
            manifest = {"dim": self.dim, "count": self.count,
                        "emb_dtype": str(self.emb_dtype),
                        "shards": self.shards}
            (self.root / "manifest.json").write_text(json.dumps(manifest))

    # -- read path ------------------------------------------------------------
    @classmethod
    def open_(cls, root) -> "PrecomputedStore":
        root = Path(root)
        man = json.loads((root / "manifest.json").read_text())
        st = cls.__new__(cls)
        st.root = root
        st.dim = man["dim"]
        st.emb_dtype = np.dtype(man["emb_dtype"])
        st.count = man["count"]
        st.shards = man["shards"]
        st._offsets = np.load(root / "offsets.npy").tolist()
        # "a+" (not "r"): a reopened store must keep serving appends —
        # §3.1 add_misses writes back into a store opened for reading.
        st._text_f = open(root / "text.jsonl", "a+", encoding="utf-8")
        st._pending_embs, st._pending_rows = [], 0
        st._lock = threading.Lock()
        return st

    def embeddings(self, mmap: bool = True) -> np.ndarray:
        """All flushed embeddings, (count, dim). Memory-mapped by default."""
        parts = [np.load(self.root / s["file"],
                         mmap_mode="r" if mmap else None)
                 for s in self.shards]
        if self._pending_embs:
            parts += self._pending_embs
        if not parts:
            return np.zeros((0, self.dim), self.emb_dtype)
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    def get_pair(self, row: int) -> Tuple[str, str]:
        with self._lock:
            self._text_f.seek(self._offsets[row])
            line = self._text_f.readline()
        d = json.loads(line)
        return d["q"], d["r"]

    def get_response(self, row: int) -> str:
        return self.get_pair(row)[1]

    # -- accounting -----------------------------------------------------------
    def storage_bytes(self) -> dict:
        index_b = sum((self.root / s["file"]).stat().st_size
                      for s in self.shards)
        text_p = self.root / "text.jsonl"
        off_p = self.root / "offsets.npy"
        meta_b = (text_p.stat().st_size if text_p.exists() else 0) \
            + (off_p.stat().st_size if off_p.exists() else 0)
        return {"index_bytes": index_b, "metadata_bytes": meta_b,
                "total_bytes": index_b + meta_b, "rows": self.count}
