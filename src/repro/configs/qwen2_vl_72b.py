"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

80L, d_model 8192, 64 heads GQA kv=8, d_ff 29568, vocab 152064, M-RoPE
(t/h/w sections 16/24/24 over head_dim/2=64). The vision frontend is a STUB:
input_specs() provides merged patch embeddings + 3D position ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    frontend="vision",
    norm_eps=1e-6,
))
