"""Deterministic word-piece-lite tokenizer.

Whitespace/punctuation word split with a frequency-built vocab and a
byte-fallback for OOV — enough to (a) count token budgets for adaptive query
masking exactly, (b) drive the tiny JAX LMs end-to-end (ids -> text -> ids).
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Iterable, List

_SPLIT = re.compile(r"\w+|[^\w\s]")

PAD, BOS, EOS, UNK = 0, 1, 2, 3
N_SPECIAL = 4
N_BYTES = 256  # byte fallback ids live at [N_SPECIAL, N_SPECIAL + 256)


class Tokenizer:
    def __init__(self, vocab: List[str]):
        self.words = list(vocab)
        self.word_to_id = {w: N_SPECIAL + N_BYTES + i
                           for i, w in enumerate(self.words)}
        self.vocab_size = N_SPECIAL + N_BYTES + len(self.words)
        # count() memoization: adaptive query masking re-budgets the same
        # chunk texts and recent queries on every candidate — tokenizing
        # them each time was >60% of offline generation wall-clock
        self._count_cache: dict = {}

    @classmethod
    def from_texts(cls, texts: Iterable[str], max_vocab: int = 8192):
        counts = Counter()
        for t in texts:
            counts.update(w.lower() for w in _SPLIT.findall(t))
        vocab = [w for w, _ in counts.most_common(max_vocab)]
        return cls(vocab)

    def encode(self, text: str, *, bos: bool = False, eos: bool = False):
        ids = [BOS] if bos else []
        for w in _SPLIT.findall(text.lower()):
            wid = self.word_to_id.get(w)
            if wid is not None:
                ids.append(wid)
            else:
                ids.extend(N_SPECIAL + b for b in w.encode("utf-8"))
        if eos:
            ids.append(EOS)
        return ids

    def decode(self, ids) -> str:
        out, byte_buf = [], []

        def flush():
            if byte_buf:
                out.append(bytes(byte_buf).decode("utf-8", errors="replace"))
                byte_buf.clear()

        for i in ids:
            i = int(i)
            if i < N_SPECIAL:
                continue
            if i < N_SPECIAL + N_BYTES:
                byte_buf.append(i - N_SPECIAL)
            else:
                flush()
                w = i - N_SPECIAL - N_BYTES
                if w < len(self.words):
                    out.append(self.words[w])
        flush()
        return " ".join(out)

    def count(self, text: str) -> int:
        n = self._count_cache.get(text)
        if n is None:
            if len(self._count_cache) >= 65536:   # bound the memo
                self._count_cache.clear()
            n = self._count_cache[text] = len(self.encode(text))
        return n
