"""DeepSeek-V2-Lite (15.7B total / 2.4B active) [arXiv:2405.04434; hf].

MLA attention (kv_lora_rank=512, decoupled RoPE 64), 64 routed experts top-6 +
2 shared experts, first layer dense (d_ff 10944). The assignment line lists both
"64e top-6" and "2 shared+160 routed"; we follow the HF V2-Lite checkpoint
config (64 routed + 2 shared, top-6) — see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                 # MoE expert intermediate size
    vocab_size=102400,
    # MLA
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,             # V2-Lite has no q compression
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    head_dim=192,              # qk_nope + qk_rope
    # MoE
    n_experts=64,
    experts_per_tok=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_dense_layers=1,
    d_ff_dense=10944,
    rope_theta=1e4,
    norm_eps=1e-6,
))
