"""Pure-jnp oracles for every kernel in this package (the allclose targets
for the interpret-mode shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mips_topk_ref(q, x, k):
    """q: (Q,D); x: (N,D) -> (vals (Q,k), idx (Q,k)) exact MIPS top-k."""
    s = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    return jax.lax.top_k(s, k)


def attention_ref(q, k, v, *, causal=True):
    """Head-major GQA attention oracle. q: (B,Hq,S,D); k,v: (B,Hkv,T,D)."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    s = s * (D ** -0.5)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, S, D)


def decode_attention_ref(q, k, v, lengths):
    """q: (B,Hq,D); k,v: (B,T,Hkv,D); attend [0, lengths[b]] inclusive."""
    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32)
    s = s * (D ** -0.5)
    mask = jnp.arange(T)[None, :] <= lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(jnp.float32),
                   v.astype(jnp.float32))
    return o.reshape(B, Hq, D)
