import os
if "--xla" not in str(os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb harness: named run-config variants per cell, each
measured exactly like the baseline dry-run (collective probes + scan-aware
jaxpr costs + full-compile memory). Results append to
experiments/perf/<cell>__<variant>.json so every hypothesis->change->
measure cycle in EXPERIMENTS.md §Perf is reproducible:

  PYTHONPATH=src python -m benchmarks.perf_iters qwen3-train sp
  PYTHONPATH=src python -m benchmarks.perf_iters --list
"""
import json
import sys
import time
from pathlib import Path

from repro.launch import dryrun as DR

OUT = Path(__file__).resolve().parents[1] / "experiments" / "perf"

# (cell-name) -> (arch, shape, {variant: run_overrides})
CELLS = {
    "qwen3-train": ("qwen3-1.7b", "train_4k", {
        "baseline": {},
        "sp": {"seq_parallel": True},
        "sp-tri": {"seq_parallel": True, "schedule": "tri"},
        "dp": {"_dp_only": True},
        "dp-tri": {"_dp_only": True, "schedule": "tri"},
    }),
    "mamba2-prefill": ("mamba2-130m", "prefill_32k", {
        "baseline": {"pin_ssm": False},
        "pin": {"pin_ssm": True},
        "pin-chunk512": {"pin_ssm": True, "ssm_chunk": 512},
        "sp": {"pin_ssm": False, "seq_parallel": True},
        "sp-chunk512": {"pin_ssm": False, "seq_parallel": True,
                        "ssm_chunk": 512},
        "sp-chunk1024": {"pin_ssm": False, "seq_parallel": True,
                         "ssm_chunk": 1024},
    }),
    "mamba2-train": ("mamba2-130m", "train_4k", {
        "baseline": {},
        "sp": {"seq_parallel": True},
        "sp-chunk512": {"seq_parallel": True, "ssm_chunk": 512},
    }),
    "deepseek-prefill": ("deepseek-v2-lite-16b", "prefill_32k", {
        "baseline": {},
        "tri": {"schedule": "tri"},
        "sp": {"seq_parallel": True},
        "sp-tri": {"seq_parallel": True, "schedule": "tri"},
        "einsum-moe": {"moe_impl": "einsum"},
    }),
    "deepseek-train": ("deepseek-v2-lite-16b", "train_4k", {
        "baseline": {},
        "sp": {"seq_parallel": True},
        "einsum-moe": {"moe_impl": "einsum"},
    }),
    "llama-train": ("llama3.2-3b", "train_4k", {
        "baseline": {},
        "sp": {"seq_parallel": True},
    }),
}


def run(cell: str, variant: str, multi_pod=False):
    arch, shape, variants = CELLS[cell]
    ov = variants[variant]
    t0 = time.time()
    res = DR.run_cell(arch, shape, multi_pod=multi_pod, probes=True,
                      run_overrides=ov or None, verbose=False)
    res["variant"] = variant
    res["overrides"] = ov
    res["wall_s"] = round(time.time() - t0, 1)
    OUT.mkdir(parents=True, exist_ok=True)
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    p = OUT / f"{cell}__{variant}__{mesh}.json"
    p.write_text(json.dumps(res, indent=1, default=str))
    r = res.get("roofline", {})
    m = res.get("memory", {})
    print(f"{cell}/{variant}: c={r.get('compute_s', 0):.4f} "
          f"m={r.get('memory_s', 0):.4f} l={r.get('collective_s', 0):.4f} "
          f"dom={r.get('dominant')} rf={r.get('roofline_frac', 0):.3f} "
          f"mem={m.get('total_gb', 0):.1f}GB ({res['wall_s']}s)")
    return res


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    if "--list" in sys.argv or not args:
        for c, (a, s, vs) in CELLS.items():
            print(f"{c}: {a} x {s} -> {list(vs)}")
        return
    cell = args[0]
    variants = args[1:] or list(CELLS[cell][2])
    for v in variants:
        run(cell, v)


if __name__ == "__main__":
    main()
