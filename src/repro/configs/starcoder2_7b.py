"""StarCoder2-7B dense [arXiv:2402.19173; hf].

32L, d_model 4608, 36 heads GQA kv=4, d_ff 18432, vocab 49152, RoPE, plain
GELU MLP (non-gated, like the released model), attention bias on.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    gated_mlp=False,
    mlp_act="gelu",
    attn_bias=True,
    rope_theta=1e5,
    norm_eps=1e-5,
))
