"""Multi-device distributed checks, run under 8 forced host devices.

Executed by tests/test_distributed.py via subprocess (the main pytest
process must keep seeing 1 device — the dry-run is the only other place the
device count is forced). Asserts:

  1. sharded MIPS top-k == flat reference on a (data=2, model=4) mesh
  2. seq-sharded GQA decode == naive decode attention
  3. seq-sharded MLA decode == naive absorbed decode
  4. EP (all-to-all) MoE == local scatter MoE, forward AND gradients
  5. param sharding rules produce valid NamedShardings for all 10 archs
  6. elastic re-shard: checkpoint saved from one mesh restores onto another
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs, reduced
from repro.core.index import FlatIndex
from repro.distributed import sharding as Sh
from repro.distributed.topk import sharded_mips_topk
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.models import moe as Moe


def check_sharded_topk(mesh):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 32)).astype(np.float32)
    q = rng.normal(size=(5, 32)).astype(np.float32)
    v, i = sharded_mips_topk(jnp.asarray(q), jnp.asarray(x), 7, mesh=mesh)
    vr, ir = FlatIndex(x).search(q, 7)
    np.testing.assert_allclose(np.asarray(v), vr, rtol=1e-5, atol=1e-5)
    sel = np.take_along_axis(q @ x.T, np.asarray(i), axis=1)
    np.testing.assert_allclose(sel, vr, rtol=1e-5, atol=1e-5)
    print("ok sharded_topk")


def check_seq_sharded_gqa(mesh):
    from repro.distributed.decode_attn import gqa_decode_seq_sharded
    rng = np.random.default_rng(1)
    B, M_, Hq, Hkv, D = 4, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)).astype(np.float32))
    k_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)).astype(np.float32))
    v_new = jnp.asarray(rng.normal(size=(B, 1, Hkv, D)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(B, M_, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, M_, Hkv, D)).astype(np.float32))
    cache_len = jnp.asarray(9, jnp.int32)

    out, kc2, vc2 = gqa_decode_seq_sharded(q, k_new, v_new, kc, vc,
                                           cache_len, mesh=mesh,
                                           batch_axes=("data",))
    # naive reference
    kc_ref = jax.lax.dynamic_update_slice(kc, k_new, (0, 9, 0, 0))
    vc_ref = jax.lax.dynamic_update_slice(vc, v_new, (0, 9, 0, 0))
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kc_ref) * (D ** -0.5)
    mask = jnp.arange(M_) <= 9
    s = jnp.where(mask[None, None, None], s.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(s, -1)
    o_ref = jnp.einsum("bkgt,btkv->bkgv", p, vc_ref).reshape(B, 1, Hq * D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref),
                               rtol=1e-6, atol=1e-6)
    print("ok seq_sharded_gqa")


def check_ep_moe_matches_scatter(mesh):
    cfg = dataclasses.replace(
        reduced(get_config("deepseek-v2-lite-16b")),
        n_experts=8, experts_per_tok=2, moe_capacity_factor=64.0)
    key = jax.random.PRNGKey(0)
    p = Moe.moe_init(key, cfg, jnp.float32)
    B, S, d = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)

    y_ref, aux_ref = Moe.moe_ffn(cfg, p, x)

    from repro.distributed.moe_parallel import moe_ffn_ep
    # model axis = 4 -> E_local = 2; S=16 % 4 == 0

    def f_ep(p, x):
        y, aux = moe_ffn_ep(cfg, p, x, mesh=mesh, ep_axis="model",
                            batch_axes=("data",))
        return y, aux

    y_ep, aux_ep = jax.jit(f_ep)(p, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)

    # gradients agree too
    g_ref = jax.grad(lambda p: (Moe.moe_ffn(cfg, p, x)[0] ** 2).sum())(p)
    g_ep = jax.grad(lambda p: (f_ep(p, x)[0] ** 2).sum())(p)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_ep)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)
    print("ok ep_moe")


def check_param_specs_all_archs(mesh):
    for name in list_configs():
        cfg = get_config(name)
        ps = jax.eval_shape(lambda c=cfg: M.init_model(
            jax.random.PRNGKey(0), c))
        specs = Sh.param_specs(ps, mesh, cfg)
        shardings = jax.tree_util.tree_map(
            lambda s: jax.sharding.NamedSharding(mesh, s), specs)
        # every spec must be consistent with its leaf's shape
        def ok(leaf, sh):
            sh.shard_shape(leaf.shape)  # raises if non-divisible
        jax.tree_util.tree_map(ok, ps, shardings)
    print("ok param_specs_all_archs")


def check_elastic_reshard(tmp, mesh_a, mesh_b):
    from repro.training import checkpoint as CK
    cfg = reduced(get_config("qwen3-1.7b"))
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    sh_a = Sh.param_shardings(params, mesh_a, cfg)
    params_a = jax.tree_util.tree_map(jax.device_put, params, sh_a)
    ck = CK.Checkpointer(tmp)
    ck.save(1, {"params": params_a}, blocking=True)
    # restore onto a DIFFERENT mesh shape
    sh_b = Sh.param_shardings(params, mesh_b, cfg)
    state, _ = ck.restore(shardings={"params": sh_b})
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ok elastic_reshard")


if __name__ == "__main__":
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_mesh((2, 4), ("data", "model"))
    check_sharded_topk(mesh)
    check_seq_sharded_gqa(mesh)
    check_ep_moe_matches_scatter(mesh)
    check_param_specs_all_archs(mesh)
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        mesh_b = make_mesh((4, 2), ("data", "model"))
        check_elastic_reshard(td, mesh, mesh_b)
    print("ALL DISTRIBUTED CHECKS PASSED")
