"""End-to-end StorInfer serving: a REAL JAX LM behind the runtime, with
parallel vector search and chunked-decode hit-cancellation (Fig 2), the
continuous-batching scheduler path, and the batched serving runtime
(microbatched admission -> one embed + one MIPS search + one batched
decode, hit slots cancelled mid-flight).

  PYTHONPATH=src python examples/storinfer_serve.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.embedder import HashEmbedder
from repro.core.generator import (GenCfg, QueryGenerator, SyntheticOracleLM,
                                  chunk_key)
from repro.core.index import FlatIndex
from repro.core.kb import build_kb, sample_user_queries
from repro.core.runtime import (BatchedRuntime, BatchedRuntimeCfg,
                                RuntimeCfg, StorInferRuntime)
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.serving.engine import BatchScheduler, Engine, Request


def main():
    kb = build_kb("squad", n_docs=10)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=1024)
    emb = HashEmbedder()

    # the on-device fallback LM (tiny config; swap real weights here)
    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              vocab_size=tok.vocab_size, n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    engine = Engine(cfg, params, tok,
                    M.RunCfg(attn_impl="naive", remat=False),
                    max_len=128, chunk=4)

    with tempfile.TemporaryDirectory() as td:
        store = PrecomputedStore(td, dim=emb.dim)
        gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok,
                             GenCfg(dedup=True))
        chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
        gen.generate(chunks, 600, store=store, seed=0)
        store.flush()

        rt = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                              engine=engine, cfg=RuntimeCfg(s_th_run=0.9))
        user = sample_user_queries(kb, 6, seed=3)
        print("=== parallel search + cancellable decode (Fig 2) ===")
        for q, _ in user:
            r = rt.query(q, max_new=16)
            print(f"[{r.source:5s} hit={r.hit} chunks={r.chunks_run} "
                  f"lat={r.latency_s:.3f}s] {q!r}")

        print("=== continuous batching with per-slot cancellation ===")
        sched = BatchScheduler(engine, batch_size=2)
        for i, (q, _) in enumerate(user[:4]):
            sched.submit(Request(rid=i, prompt=q, max_new=8))
        # a StorInfer hit arrives for request 1 -> cancel mid-flight
        sched.cancel(1)
        done = sched.run_to_completion()
        for r in sorted(done, key=lambda r: r.rid):
            print(f"req {r.rid}: cancelled={r.cancelled} "
                  f"tokens={len(r.out_ids)}")

        print("=== batched StorInfer runtime (auto-tiered index) ===")
        with BatchedRuntime.from_store(
                store, emb, engine=engine,
                cfg=BatchedRuntimeCfg(s_th_run=0.9, max_batch=8,
                                      max_wait_s=0.02)) as brt:
            futs = [brt.submit(q, max_new=8) for q, _ in user]
            for (q, _), f in zip(user, futs):
                r = f.result(timeout=120)
                print(f"[{r.source:5s} hit={r.hit} "
                      f"cancelled={r.cancelled}] {q!r}")
            s = brt.stats
            print(f"stats: {s.queries} queries, {s.hits} hits "
                  f"({s.hit_rate:.0%}), {s.llm_cancelled} decodes "
                  f"hit-cancelled, {s.batches} microbatches")


if __name__ == "__main__":
    main()
