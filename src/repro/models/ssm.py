"""Mamba2 (SSD — state-space duality) block: chunked scan + single-step decode.

Follows the ssd_minimal_discrete formulation of arXiv:2405.21060 with the
inter-chunk recurrence as a ``lax.scan`` (O(n_chunks), required for the 500k
long-context shape) instead of the quadratic chunk-segsum of the minimal code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, rmsnorm_init, gated_rmsnorm


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def ssm_init(key, cfg, dtype=None):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    H = cfg.n_ssm_heads
    G, N, W = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_conv
    dtype = dtype or jnp.dtype(cfg.dtype)
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (di), xBC (di + 2GN), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (W, conv_ch), jnp.float32)
                   * (W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = -exp(A_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------


def _split_in_proj(cfg, zxbcdt):
    di = cfg.d_inner_ssm
    G, N, H = cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, xBC, dt


def causal_conv(p, xBC):
    """Depthwise causal conv1d over (B, S, C)."""
    W = p["conv_w"].shape[0]
    x = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    # depthwise: sum over the window of shifted slices (W is tiny, 4)
    S = xBC.shape[1]
    out = sum(x[:, i:i + S, :] * p["conv_w"][i][None, None, :] for i in range(W))
    return jax.nn.silu(out + p["conv_b"])


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} x[k], -inf j>i."""
    c = jnp.cumsum(x, axis=-1)
    L = c[..., :, None] - c[..., None, :]
    Q = x.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssd_chunked(xdt, dA, Bm, Cm, chunk, h0=None):
    """SSD over a full sequence.

    xdt: (B, S, H, P)  — inputs pre-multiplied by dt
    dA : (B, S, H)     — log decay per step (dt * A, A negative)
    Bm, Cm: (B, S, G, N) with G | H (broadcast groups)
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    B, S, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    def c(t):  # (B, S, ...) -> (B, nc, chunk, ...)
        return t.reshape((B, nc, chunk) + t.shape[2:])

    x_, a_, b_, c_ = c(xdt), c(dA), c(Bm), c(Cm)
    b_ = jnp.repeat(b_, rep, axis=3)                  # (B,nc,Q,H,N)
    c_ = jnp.repeat(c_, rep, axis=3)
    a_ = jnp.moveaxis(a_, -1, 2)                       # (B,nc,H,Q)
    a_cum = jnp.cumsum(a_, axis=-1)                    # (B,nc,H,Q)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(a_.astype(jnp.float32)))       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", c_, b_).astype(jnp.float32)
    y_diag = jnp.einsum("bchqs,bchqs,bcshp->bcqhp",
                        scores, L, x_.astype(jnp.float32))

    # 2. per-chunk final states
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum).astype(jnp.float32)  # (B,nc,H,Q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        b_.astype(jnp.float32), decay_to_end, x_.astype(jnp.float32))

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1].astype(jnp.float32))            # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    h_final, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                # (B,nc,H,P,N)

    # 4. contribution of entering state to each position
    state_decay = jnp.exp(a_cum).astype(jnp.float32)   # (B,nc,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       c_.astype(jnp.float32), h_prev, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(xdt.dtype), h_final


def ssm_forward(cfg, p, x, state=None, conv_state=None, chunk=None,
                constrain=None):
    """Full-sequence (train/prefill) Mamba2 block. Returns (y, (h, conv_state)).

    ``constrain(t, batch_dim)``: optional sharding pin applied to the wide
    intermediates — without it GSPMD speculatively seq-shards the SSD scan
    and pays halo collective-permutes every chunk (measured 1.1 GB/layer on
    mamba2 prefill_32k).
    """
    B, S, _ = x.shape
    chunk = chunk or cfg.ssm_chunk
    cb = constrain or (lambda t, b: t)
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    di = cfg.d_inner_ssm
    z, xBC_raw, dt = _split_in_proj(cfg, dense(p["in_proj"], x))
    xBC_raw = cb(xBC_raw, 0)
    xBC = cb(causal_conv(p, xBC_raw), 0)
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])           # (B,S,H)
    A = -jnp.exp(p["A_log"])                                              # (H,)
    dA = dt * A                                                           # (B,S,H)
    xdt = xs * dt[..., None].astype(xs.dtype)
    xdt = cb(xdt, 0)
    y, h = ssd_chunked(xdt, dA, Bm, Cm, min(chunk, S), h0=state)
    y = cb(y, 0)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di)
    y = gated_rmsnorm(p["norm"], y, z, cfg.norm_eps)
    # conv state for subsequent decode = last W-1 *pre-conv* inputs
    W = cfg.ssm_conv
    pad = jnp.pad(xBC_raw, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0)))
    new_conv_state = pad[:, -(W - 1):, :]
    return dense(p["out_proj"], y), (h, new_conv_state)


def ssm_decode(cfg, p, x, state, conv_state):
    """Single-token decode. state: (B,H,P,N) f32; conv_state: (B, W-1, C)."""
    B, S, _ = x.shape  # S == 1
    H, P = cfg.n_ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_n_groups, cfg.ssm_state
    di = cfg.d_inner_ssm
    W = cfg.ssm_conv
    z, xBC, dt = _split_in_proj(cfg, dense(p["in_proj"], x))
    # conv over (conv_state ++ xBC)
    window = jnp.concatenate([conv_state, xBC], axis=1)      # (B, W, C)
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv)[:, None, :]
    new_conv_state = window[:, 1:, :]
    xs = xBC[..., :di].reshape(B, H, P)
    Bm = xBC[..., di:di + G * N].reshape(B, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=1)                          # (B,H,N)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                     # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm.astype(jnp.float32),
                     xs.astype(jnp.float32))
    h = state * dec[..., None, None] + upd                    # (B,H,P,N)
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y.astype(xs.dtype) + xs * p["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, 1, di)
    y = gated_rmsnorm(p["norm"], y, z, cfg.norm_eps)
    return dense(p["out_proj"], y), (h, new_conv_state)
