"""Pallas TPU kernel: quantized MIPS over int8 store tiles (DESIGN.md §3,
the device-resident serving path).

The store's embedding shards are symmetric per-row int8 (values +
one f32 scale per row, see core/store.py); queries are quantized the same
way at dispatch. Each grid step scores one (TILE_N, D) int8 tile against
the resident int8 query block on the MXU with int32 accumulation —
exact: |s| <= 127*127*D stays below 2^24 for D <= 1040, so the f32 cast
of the accumulator is lossless at our D=384 — then fuses the per-row
scale dequant (s * q_scale * x_scale) and the streaming tile top-k
(``tile_topk``, shared with the fp32 kernel) before anything leaves VMEM.
HBM traffic per tile is TILE_N * (D + 4) bytes instead of the fp32 path's
4 * TILE_N * D — the 4x bandwidth cut that motivates the whole path.

The dequant multiply order (int32 -> f32, then * q_scale, then * x_scale)
and the (value desc, index asc) tie-break are part of the kernel contract:
tests validate the result BIT-FOR-BIT against the numpy reference
(ref.mips_topk_int8_ref) in interpret mode.

Note on real-TPU tiling: int8 VMEM tiles are (32, 128); small Q blocks
are sublane-padded by Mosaic, which wastes a few rows but stays correct.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mips_topk import NEG, tile_topk


def _mips_int8_kernel(q_ref, qs_ref, x_ref, xs_ref, vals_ref, idx_ref, *,
                      k, tile_n, n_real):
    i = pl.program_id(0)
    q = q_ref[...]                                    # (Q, D) int8
    x = x_ref[...]                                    # (TILE_N, D) int8
    s = jnp.dot(q, x.T, preferred_element_type=jnp.int32)  # exact int32
    # fused dequant: one f32 (Q, TILE_N) block, never materialized off-chip
    s = s.astype(jnp.float32) * qs_ref[...] * xs_ref[...].T
    row_global = i * tile_n + jax.lax.broadcasted_iota(jnp.int32,
                                                       s.shape, 1)
    s = jnp.where(row_global < n_real, s, NEG)
    vals, idx = tile_topk(s, k)
    vals_ref[0] = vals
    idx_ref[0] = idx


def mips_topk_int8_pallas(q, q_scale, x, x_scale, k, *, tile_n=512,
                          interpret=True):
    """q: (Q, D) int8; q_scale: (Q,) f32; x: (N, D) int8; x_scale: (N,) f32.
    Returns per-tile candidates (vals (nt, Q, k) f32, idx-global (nt, Q, k))
    where vals are dequantized scores q_scale[r] * x_scale[c] * <q_r, x_c>.
    """
    Q, D = q.shape
    N = x.shape[0]
    nt = -(-N // tile_n)
    N_pad = nt * tile_n
    if N_pad != N:                # zero rows + unit scales; masked by n_real
        x = jnp.pad(x, ((0, N_pad - N), (0, 0)))
        x_scale = jnp.pad(x_scale, (0, N_pad - N), constant_values=1.0)
    Dp = -(-D // 128) * 128
    if Dp != D:                   # zero-padding is exact for the int32 dot
        q = jnp.pad(q, ((0, 0), (0, Dp - D)))
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    qs = q_scale.astype(jnp.float32).reshape(Q, 1)
    xs = x_scale.astype(jnp.float32).reshape(N_pad, 1)

    kernel = functools.partial(_mips_int8_kernel, k=k, tile_n=tile_n,
                               n_real=N)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((Q, Dp), lambda i: (0, 0)),        # q resident
            pl.BlockSpec((Q, 1), lambda i: (0, 0)),         # q scales
            pl.BlockSpec((tile_n, Dp), lambda i: (i, 0)),   # x streamed
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),    # x scales
        ],
        out_specs=[
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt, Q, k), jnp.float32),
            jax.ShapeDtypeStruct((nt, Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, qs, x, xs)
    offs = (jnp.arange(nt, dtype=jnp.int32) * tile_n)[:, None, None]
    return vals, idx + offs
