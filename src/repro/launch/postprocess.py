import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Refresh jaxpr-derived costs + roofline terms in existing dry-run JSONs
(trace-only — no recompilation; collective bytes and memory_analysis are
kept from the original compile)."""
import json
import sys
from pathlib import Path

import jax

from repro.launch import costs as C
from repro.launch import specs as SP
from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS, RESULTS_DIR)
from repro.launch.mesh import make_production_mesh
from repro.configs import SHAPES, get_config


def refresh(path: Path):
    d = json.loads(path.read_text())
    if d.get("status") != "ok":
        return "skip"
    mesh = make_production_mesh(multi_pod=(d["mesh"] == "pod2x16x16"))
    plan = SP.build_cell(d["arch"], d["shape"], mesh)
    jc = C.fn_costs(plan.fn, *plan.arg_structs)
    n = mesh.size
    d["jaxpr"] = {"flops_global": jc["flops"], "bytes_global": jc["bytes"],
                  "warnings": jc["warnings"]}
    coll = d.get("collectives", {}).get("bytes_per_dev") or 0.0
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    flops_chip = jc["flops"] / n
    bytes_chip = jc["bytes"] / n
    terms = {"compute_s": flops_chip / PEAK_FLOPS,
             "memory_s": bytes_chip / HBM_BW,
             "collective_s": coll / ICI_BW}
    dom = max(terms, key=terms.get)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if plan.kind != "decode"
                                   else 1)
    model_flops = (6 if plan.kind == "train" else 2) * n_active * tokens
    d["roofline"] = dict(
        terms, dominant=dom, flops_per_chip=flops_chip,
        bytes_per_chip=bytes_chip, collective_bytes_per_chip=coll,
        model_flops_global=model_flops,
        useful_flops_frac=model_flops / max(jc["flops"], 1.0),
        bound_step_time_s=max(terms.values()),
        roofline_frac=terms["compute_s"] / max(max(terms.values()), 1e-30))
    path.write_text(json.dumps(d, indent=1, default=str))
    return "ok"


def main():
    for p in sorted(RESULTS_DIR.glob("*.json")):
        try:
            r = refresh(p)
        except Exception as e:
            r = f"ERR {type(e).__name__}: {e}"
        print(p.name, r, flush=True)


if __name__ == "__main__":
    main()
