"""Qwen2.5-32B dense [hf:Qwen/Qwen2.5-32B; hf].

64L, d_model 5120, 40 heads GQA kv=8, d_ff 27648, vocab 152064, QKV bias.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_theta=1e6,
    norm_eps=1e-6,
))
