"""AdamW with f32 master params, sharded like the model (ZeRO-3 style).

Optimizer state = {master (f32 copy of params), m, v (f32), step (i32)}.
Every state leaf inherits the param's PartitionSpec, so m/v/master shard
identically to the weights (no replicated optimizer memory). The model
params stay bf16 (compute dtype); ``update`` writes them as a cast of the
f32 master after the Adam step — the standard mixed-precision recipe.

Optional int8 gradient compression with error feedback lives in
``repro.training.compression`` and is applied to the gradient pytree before
``update`` (off for baselines).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"          # cosine | constant
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(ocfg: AdamWCfg, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    if ocfg.schedule == "constant":
        return ocfg.lr * warm
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * warm * (ocfg.min_lr_frac + (1 - ocfg.min_lr_frac) * cos)


def init(params):
    f32 = lambda p: p.astype(jnp.float32)
    z32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree_util.tree_map(f32, params),
        "m": jax.tree_util.tree_map(z32, params),
        "v": jax.tree_util.tree_map(z32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree_util.tree_leaves(tree)))


def update(ocfg: AdamWCfg, grads, state, params):
    """Returns (new_params (param dtype), new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if ocfg.clip_norm else jnp.float32(1.0)
    lr = lr_at(ocfg, step)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def one(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + ocfg.eps)
        # decoupled weight decay on matrices only (skip vectors/scalars)
        if master.ndim >= 2:
            upd = upd + ocfg.weight_decay * master
        master = master - lr * upd
        return m, v, master, master.astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    flat_p = treedef.flatten_up_to(params)
    out = [one(*t) for t in zip(flat_g, flat_m, flat_v, flat_w, flat_p)]
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
        "master": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    for k in state:  # carry through extra state (e.g. compression error fb)
        if k not in new_state:
            new_state[k] = state[k]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[3] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs_tree):
    """PartitionSpec tree for the optimizer state given the param specs."""
    from jax.sharding import PartitionSpec as P
    return {
        "master": param_specs_tree,
        "m": param_specs_tree,
        "v": param_specs_tree,
        "step": P(),
    }
