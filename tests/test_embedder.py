"""Embedders: hash-SRP semantic ordering; MiniLM JAX encoder contrastive
training improves paraphrase alignment."""
import numpy as np
import pytest

from repro.core.embedder import EncoderCfg, HashEmbedder, MiniLMEncoder
from repro.core.kb import TEMPLATES, build_kb, render_query
from repro.core.tokenizer import Tokenizer


def test_hash_embedder_orders_similarity():
    emb = HashEmbedder()
    e = emb.encode([
        "what is the height of aurora bridge?",
        "what is the height of the aurora bridge?",   # near-duplicate
        "tell me the height of aurora bridge",        # paraphrase
        "who founded the meridian institute?",        # unrelated
    ])
    sims = e @ e[0]
    assert sims[1] > sims[2] > sims[3]
    assert sims[1] > 0.85
    np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, rtol=1e-5)


def test_hash_embedder_deterministic():
    a = HashEmbedder().encode(["hello world"])
    b = HashEmbedder().encode(["hello world"])
    np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_minilm_contrastive_training_improves_alignment():
    kb = build_kb("squad", n_docs=6)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    enc = MiniLMEncoder(tok, EncoderCfg(vocab_size=tok.vocab_size,
                                        dim=64, n_layers=2, n_heads=4,
                                        d_ff=128, max_len=24), seed=0)
    # paraphrase pairs: same fact, two templates
    pairs = []
    for f in kb.facts[:64]:
        pairs.append((render_query(f, 0), render_query(f, 2)))

    def pair_sim():
        a = enc.encode([p[0] for p in pairs[:32]])
        b = enc.encode([p[1] for p in pairs[:32]])
        pos = float(np.mean(np.sum(a * b, axis=1)))
        neg = float(np.mean(a @ b.T)) # includes negatives
        return pos - neg

    import numpy as _np
    before = pair_sim()
    losses = enc.train_contrastive(pairs, steps=80, bs=16, lr=2e-3)
    after = pair_sim()
    assert _np.mean(losses[-10:]) < _np.mean(losses[:10]), \
        (losses[:3], losses[-3:])
    assert after > before - 0.02, (before, after)


def test_minilm_encode_bucketing_consistent():
    """Padded power-of-two buckets + max_batch chunking must not change
    per-row embeddings (padding rows carry zero mask; rows are sliced off
    before return)."""
    kb = build_kb("squad", n_docs=3)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    enc = MiniLMEncoder(tok, EncoderCfg(vocab_size=tok.vocab_size,
                                        dim=32, n_layers=1, n_heads=2,
                                        d_ff=64, max_len=16), seed=0,
                        max_batch=4)
    texts = [render_query(f, i % len(TEMPLATES))
             for i, f in enumerate(kb.facts[:11])]
    full = enc.encode(texts)                    # 11 -> chunks of 4,4,3
    assert full.shape == (11, 32)
    np.testing.assert_allclose(enc.encode(texts[:3]), full[:3],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(enc.encode([texts[7]]), full[7:8],
                               rtol=1e-5, atol=1e-6)
    assert enc.encode([]).shape == (0, 32)
