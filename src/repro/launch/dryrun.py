import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh, extract roofline terms, and persist JSON.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices to build the
(pod=2, data=16, model=16) mesh. (Smoke tests / benches import jax normally
and see 1 device — this env var is intentionally NOT set globally.)

Per cell:
  1. FULL-depth compile (scan over layers)     -> memory_analysis (fits?),
     raw cost_analysis, collective op census.
  2. jaxpr walk (scan-aware)                   -> exact FLOPs + bytes model.
  3. depth-1/depth-2 UNROLLED probe compiles   -> per-layer collective bytes
     (collectives inside while bodies appear once in HLO text regardless of
     trip count — measured; hence unrolled probes + linear extrapolation).
     Hybrid/enc-dec stacks are python-unrolled already: parsed directly.
  4. Roofline terms (TPU v5e): compute = FLOPs/chip / 197e12, memory =
     bytes/chip / 819e9, collective = coll_bytes/chip / (3 links x ~50GB/s
     usable per link -> harness uses 1 link conservatively; see report).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod/--single] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import SHAPES, get_config, list_configs
from repro.launch import costs as C
from repro.launch import hlo_collectives as HC
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (1 link assumed engaged)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

ARCHS = [a for a in
         ("deepseek-v2-lite-16b", "grok-1-314b", "whisper-base",
          "llama3.2-3b", "starcoder2-7b", "qwen3-1.7b", "qwen2.5-32b",
          "zamba2-1.2b", "qwen2-vl-72b", "mamba2-130m")]


def _compile(plan):
    jfn = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                  out_shardings=plan.out_shardings,
                  donate_argnums=plan.donate)
    t0 = time.time()
    lowered = jfn.lower(*plan.arg_structs)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return lowered, compiled, t1 - t0, t2 - t1


def run_cell(arch, shape_name, *, multi_pod, probes=True, run_overrides=None,
             accum=None, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = SP.skip_reason(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    if reason:
        out["status"] = reason
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    plan = SP.build_cell(arch, shape_name, mesh, run_overrides=run_overrides,
                         accum=accum)
    out["notes"] = plan.notes

    # ---- 1. full-depth compile -------------------------------------------
    lowered, compiled, t_low, t_comp = _compile(plan)
    ma = compiled.memory_analysis()
    out["timings"] = {"lower_s": round(t_low, 2), "compile_s": round(t_comp, 2)}
    total = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
             + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    out["memory"] = {
        "args_gb": ma.argument_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "out_gb": ma.output_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        "total_gb": total / 1e9,
        "fits_16gb": total < 16e9,
    }
    ca = compiled.cost_analysis() or {}
    out["xla_cost"] = {"flops_per_dev": ca.get("flops", 0.0),
                       "bytes_per_dev": ca.get("bytes accessed", 0.0),
                       "note": "scan bodies counted once (see costs.py)"}
    full_coll = HC.collective_bytes(compiled.as_text())
    out["collectives_full_hlo"] = {"counts": full_coll["counts"],
                                   "non_entry": full_coll["non_entry_collectives"]}

    # ---- 2. jaxpr walk (exact flops, bytes model) ------------------------
    jc = C.fn_costs(plan.fn, *plan.arg_structs)
    out["jaxpr"] = {"flops_global": jc["flops"], "bytes_global": jc["bytes"],
                    "warnings": jc["warnings"]}

    # ---- 3. collective bytes via unrolled probes -------------------------
    unrolled_families = ("hybrid",)
    coll_total = None
    if cfg.family in unrolled_families or cfg.is_encoder_decoder:
        coll_total = full_coll["total_bytes"]
        out["collectives"] = {"method": "direct(full unrolled stack)",
                              "bytes_per_dev": coll_total,
                              "by_op": full_coll["bytes"]}
    elif probes:
        d1, d2, full_stack, s1 = SP.probe_depths(cfg)
        probe_res = []
        for dcfg in (d1, d2):
            pplan = SP.build_cell(arch, shape_name, mesh, cfg=dcfg,
                                  run_overrides=dict(
                                      (run_overrides or {}),
                                      scan_layers=False),
                                  accum=accum)
            _, pc, _, _ = _compile(pplan)
            probe_res.append(HC.collective_bytes(pc.as_text()))
        c1, c2 = (p["total_bytes"] for p in probe_res)
        per_layer = c2 - c1
        coll_total = c1 + per_layer * (full_stack - s1)
        out["collectives"] = {
            "method": "unrolled depth-1/2 probes + linear extrapolation",
            "bytes_per_dev": coll_total,
            "probe_bytes": [c1, c2],
            "per_layer_bytes": per_layer,
            "non_entry_flags": [p["non_entry_collectives"]
                                for p in probe_res],
            "by_op_probe2": probe_res[1]["bytes"],
        }

    # ---- 4. roofline terms ------------------------------------------------
    flops_chip = jc["flops"] / n_dev
    bytes_chip = jc["bytes"] / n_dev
    t_compute = flops_chip / PEAK_FLOPS
    t_memory = bytes_chip / HBM_BW
    t_coll = (coll_total or 0.0) / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    # model flops: 6*N_active*D train, 2*N_active*D inference
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if plan.kind != "decode"
                                   else 1)
    model_flops = (6 if plan.kind == "train" else 2) * n_active * tokens
    out["roofline"] = dict(
        terms, dominant=dom,
        flops_per_chip=flops_chip, bytes_per_chip=bytes_chip,
        collective_bytes_per_chip=coll_total,
        model_flops_global=model_flops,
        useful_flops_frac=model_flops / max(jc["flops"], 1.0),
        bound_step_time_s=max(terms.values()),
        roofline_frac=t_compute / max(max(terms.values()), 1e-30),
    )
    if verbose:
        print(json.dumps({k: out[k] for k in
                          ("arch", "shape", "mesh", "memory", "roofline")},
                         indent=1, default=str))
    return out


def cell_path(arch, shape_name, mesh_name):
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--single", action="store_true",
                    help="single-pod 16x16 (default when not --multipod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    meshes = [args.multipod] if not args.both_meshes else [False, True]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            path = cell_path(arch, shape_name, mesh_name)
            if path.exists() and not args.force:
                print(f"[skip-cached] {path.name}")
                continue
            t0 = time.time()
            try:
                res = run_cell(arch, shape_name, multi_pod=mp,
                               probes=not args.no_probes, accum=args.accum,
                               verbose=False)
            except Exception as e:
                failures += 1
                res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": f"FAIL: {type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            res["wall_s"] = round(time.time() - t0, 1)
            path.write_text(json.dumps(res, indent=1, default=str))
            print(f"[{res['status'][:60]:<60}] {path.name} "
                  f"({res['wall_s']}s)")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
