"""Batched StorInfer serving throughput, three sections:

1. **batched vs sequential** — `StorInfer.query` (the paper's Fig-2 loop)
   vs `StorInfer.query_batch` on the SAME system; amortization is the
   whole story (one embed + one MIPS dispatch per microbatch). Floor:
   >= 4x queries/sec at batch 32.
2. **pipelined serving** — a mixed 50/50 hit/miss stream through the
   staged `ServingPipeline` (facade `serve()`/`submit()`, a real
   smoke-arch engine decoding the misses). Measures the hit-latency
   decoupling the paper's "instantly returns the stored response" story
   requires: hits resolve at MIPS-search time, never waiting on any miss
   decode. Floor: hit-path p50 <= 0.5x miss-path p50 (enforced in smoke
   mode too — the margin is orders of magnitude when decode is real).
3. **quantized flat scan** — the device-resident int8 path vs the pre-PR
   fp32 flat scan (kept verbatim below as `_LegacyFlatIndex`): same rows,
   serving-mix queries, N >= 100K in full mode. Floors: top-1 agreement
   with exact fp32 >= 0.99 on would-hit queries, int8 store bytes <= 30%
   of the fp32 store, and (full mode, where N is large enough for the
   bandwidth effect to dominate timing noise) scan throughput >= the
   configured floor (default 1.4x tripwire; measured ~2x at N=100K).

Emits experiments/bench/BENCH_batched_serve.json AND a repo-root
BENCH_serve.json (the machine-readable perf-trajectory point CI uploads,
now carrying hit/miss p50+p99 for the pipelined path).
Exits non-zero below any floor.

  PYTHONPATH=src python benchmarks/bench_batched_serve.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import out_write
from repro.api import EngineCfg, StorInfer, SystemCfg, make_embedder, \
    make_index, tier_of
from repro.core.runtime import BatchedRuntimeCfg
from repro.core.store import PrecomputedStore


def build_synth_store(root, emb, n_rows: int, batch: int = 2048):
    """Write synthetic query/response pairs to ``root`` and close the
    store (reopen via ``StorInfer.open``); embeddings come from the real
    embedder so sequential and batched paths search identical data."""
    store = PrecomputedStore(root, dim=emb.dim)
    for lo in range(0, n_rows, batch):
        hi = min(lo + batch, n_rows)
        qs = [f"synthetic question {i} about topic {i % 97} and "
              f"entity {i % 31}" for i in range(lo, hi)]
        rs = [f"stored answer number {i}." for i in range(lo, hi)]
        store.add_batch(emb.encode(qs), qs, rs)
    store.close()


def user_queries(n: int, n_store: int, hit_frac: float = 0.5, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for j in range(n):
        if rng.random() < hit_frac:
            i = int(rng.integers(0, n_store))
            out.append(f"synthetic question {i} about topic {i % 97} and "
                       f"entity {i % 31}")
        else:
            out.append(f"novel unseen query {j} zebra {rng.integers(1e6)}")
    return out


def pcts(lat_s):
    a = np.asarray(lat_s)
    return {"p50_ms": float(np.percentile(a, 50) * 1e3),
            "p99_ms": float(np.percentile(a, 99) * 1e3),
            "mean_ms": float(a.mean() * 1e3)}


# ---------------------------------------------------------------------------
# Section 2: pipelined serving — hit p50 decoupled from miss decode
# ---------------------------------------------------------------------------


def bench_pipelined_serving(n_store, n_q, batch, s_th, ratio_floor,
                            decode_slots=4, max_new=8, seed=1):
    """Mixed 50/50 hit/miss stream through the staged pipeline end to end
    (facade ``serve()``/``submit()``) with a real smoke-arch engine behind
    the misses. The whole point of the stage decoupling: hit futures
    resolve at MIPS-search time, so hit p50 must sit far below miss p50
    instead of being gated by the slowest miss in the microbatch."""
    with tempfile.TemporaryDirectory() as td:
        build_synth_store(td, make_embedder("hash"), n_store)
        cfg = SystemCfg(
            s_th_run=s_th,
            engine=EngineCfg(smoke=True, max_len=96, chunk=8),
            batched=BatchedRuntimeCfg(max_batch=batch, max_wait_s=0.002),
            decode_slots=decode_slots,
            queue_depth=max(64, 2 * n_q))
        queries = user_queries(n_q, n_store, hit_frac=0.5, seed=seed)
        with StorInfer.open(td, cfg) as si:
            with si.serve():
                # warm the jit caches (search shape + prefill/decode) on a
                # throwaway hit + miss before timing anything
                warm = [si.submit("synthetic question 0 about topic 0 "
                                  "and entity 0", max_new=max_new),
                        si.submit("warmup novel zebra query xyz",
                                  max_new=max_new)]
                [f.result(timeout=600) for f in warm]

                t0 = time.perf_counter()
                futs = [si.submit(q, max_new=max_new) for q in queries]
                results = [f.result(timeout=600) for f in futs]
                wall = time.perf_counter() - t0
            snap = si.stats().pipeline

        hit_lat = [r.latency_s for r in results if r.hit]
        miss_lat = [r.latency_s for r in results if not r.hit]
        assert hit_lat and miss_lat, \
            "mixed workload degenerated to one class — floor is vacuous"
        hit_p, miss_p = pcts(hit_lat), pcts(miss_lat)
        ratio = hit_p["p50_ms"] / miss_p["p50_ms"]
        section = {
            "n_store": n_store, "n_queries": n_q,
            "decode_slots": decode_slots, "max_new": max_new,
            "hit_rate": len(hit_lat) / n_q,
            "hit": hit_p, "miss": miss_p,
            "p50_ratio": ratio, "ratio_floor": ratio_floor,
            "qps": n_q / wall,
            "stages": snap["stages"],
            "decode_reuse": snap.get("decode_slots"),
        }
        print(f"pipelined serving: store={n_store} queries={n_q} "
              f"(hit_rate={section['hit_rate']:.2f}) "
              f"decode_slots={decode_slots}")
        print(f"  hit:  p50={hit_p['p50_ms']:8.2f}ms "
              f"p99={hit_p['p99_ms']:8.2f}ms  (n={len(hit_lat)})")
        print(f"  miss: p50={miss_p['p50_ms']:8.2f}ms "
              f"p99={miss_p['p99_ms']:8.2f}ms  (n={len(miss_lat)})")
        print(f"  hit/miss p50 ratio: {ratio:.3f} "
              f"(floor {ratio_floor}) — {n_q / wall:.1f} q/s end-to-end")
        reuse = section["decode_reuse"] or {}
        if reuse:
            print(f"  decode slots: {reuse['slots']} slots served "
                  f"{reuse['admitted']} misses over {reuse['waves']} waves")

        failures = []
        if ratio > ratio_floor:
            failures.append(
                f"pipelined hit p50 {hit_p['p50_ms']:.2f}ms is "
                f"{ratio:.2f}x miss p50 {miss_p['p50_ms']:.2f}ms "
                f"(floor {ratio_floor}x) — hits are gated by miss decode")
        return section, failures


# ---------------------------------------------------------------------------
# Section 3: device-resident int8 flat scan vs the pre-PR fp32 path
# ---------------------------------------------------------------------------


class _LegacyFlatIndex:
    """The pre-PR FlatIndex scan, verbatim: fp32 (N, D) resident,
    jit(q @ x.T + top_k) per search. Kept here as the measured baseline
    so the reported speedup is against the REAL old code path, not a
    strawman."""

    def __init__(self, embs):
        self.embs = jnp.asarray(np.asarray(embs, np.float32))
        self._search = jax.jit(self._impl, static_argnums=(2,))

    @staticmethod
    def _impl(q, embs, k):
        return jax.lax.top_k(q @ embs.T, k)

    def search(self, queries, k):
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = self._search(q, self.embs, k)
        return np.asarray(v), np.asarray(i)


def _scan_qps(index, queries, batch, reps=3):
    """Best-of-``reps`` queries/sec over the full query set (min total
    wall-clock de-noises a shared box; the jit cache is warmed first)."""
    index.search(queries[:batch], 1)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for lo in range(0, len(queries), batch):
            index.search(queries[lo:lo + batch], 1)
        best = min(best, time.perf_counter() - t0)
    return len(queries) / best


def _fill(store, embs, batch=8192):
    for lo in range(0, embs.shape[0], batch):
        hi = min(lo + batch, embs.shape[0])
        store.add_batch(embs[lo:hi],
                        [f"q{i}" for i in range(lo, hi)],
                        [f"r{i}" for i in range(lo, hi)])
    store.close()


def bench_quantized_flat(n_rows, n_q, batch, s_th, speedup_floor,
                         enforce_speedup, seed=0):
    rng = np.random.default_rng(seed)
    dim = 384
    embs = rng.normal(size=(n_rows, dim)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    # serving mix: half near-duplicates of stored rows (the hit regime the
    # paper's threshold race depends on), half novel queries. Noise sigma
    # 0.01 keeps the duplicates ABOVE s_th (cos ~ 1/sqrt(1 + 0.01^2 * D)
    # ~ 0.98 at D=384) so the would-hit recall floor below actually
    # compares queries — 0.05 would push every duplicate under 0.9 and
    # make the floor vacuously true
    n_hit = n_q // 2
    hit_q = embs[rng.integers(0, n_rows, n_hit)] \
        + 0.01 * rng.normal(size=(n_hit, dim)).astype(np.float32)
    nov_q = rng.normal(size=(n_q - n_hit, dim)).astype(np.float32)
    queries = np.concatenate([hit_q, nov_q]).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)

    with tempfile.TemporaryDirectory() as td:
        td = Path(td)
        _fill(PrecomputedStore(td / "fp32", dim=dim, emb_dtype="float32"),
              embs)
        _fill(PrecomputedStore(td / "int8", dim=dim, emb_dtype="int8"),
              embs)
        st32 = PrecomputedStore.open_(td / "fp32")
        st8 = PrecomputedStore.open_(td / "int8")
        bytes32 = st32.storage_bytes()["index_bytes"]
        bytes8 = st8.storage_bytes()["index_bytes"]

        legacy = _LegacyFlatIndex(embs)
        quant = make_index("flat", st8)       # DeviceStore-resident int8

        legacy_qps = _scan_qps(legacy, queries, batch)
        quant_qps = _scan_qps(quant, queries, batch)

        # fidelity: exact fp32 scores from the legacy arm ARE the oracle
        v32, i32 = legacy.search(queries, 1)
        v8, i8 = quant.search(queries, 1)
        would_hit = v32[:, 0] >= s_th
        n_would_hit = int(would_hit.sum())
        recall_hits = float((i8[would_hit, 0] ==
                             i32[would_hit, 0]).mean()) \
            if n_would_hit else float("nan")
        recall_all = float((i8[:, 0] == i32[:, 0]).mean())
        hit_flip = float((np.asarray(v8[:, 0] >= s_th) !=
                          would_hit).mean())
        st32.close()
        st8.close()

    speedup = quant_qps / legacy_qps
    bytes_ratio = bytes8 / bytes32
    section = {
        "n_rows": n_rows, "n_queries": n_q, "batch": batch, "dim": dim,
        "s_th_run": s_th,
        "resident": quant.dev.layout,
        "legacy_fp32_qps": legacy_qps, "int8_qps": quant_qps,
        "scan_speedup": speedup, "speedup_floor": speedup_floor,
        "speedup_enforced": bool(enforce_speedup),
        "recall_at1_hits": recall_hits, "n_would_hit": n_would_hit,
        "recall_at1_all": recall_all,
        "hit_decision_flip_rate": hit_flip,
        "int8_bytes": int(bytes8), "fp32_bytes": int(bytes32),
        "bytes_ratio": bytes_ratio,
    }
    print(f"quantized flat scan: N={n_rows} batch={batch} "
          f"({section['resident']} residency)")
    print(f"  legacy fp32: {legacy_qps:8.1f} q/s   int8 device-resident: "
          f"{quant_qps:8.1f} q/s   speedup {speedup:.2f}x "
          f"(floor {speedup_floor}x"
          f"{', enforced' if enforce_speedup else ', report-only'})")
    print(f"  recall@1 vs fp32: {recall_hits:.4f} on {n_would_hit} "
          f"would-hit queries (floor 0.99), {recall_all:.4f} overall; "
          f"hit-decision flips {hit_flip:.4f}")
    print(f"  store bytes: int8 {bytes8 / 1e6:.1f} MB vs fp32 "
          f"{bytes32 / 1e6:.1f} MB = {bytes_ratio:.3f} (floor 0.30)")

    failures = []
    # guard against a vacuous floor: the duplicate half of the mix must
    # actually clear the threshold for the recall comparison to exist
    if n_would_hit < n_hit // 2:
        failures.append(
            f"only {n_would_hit}/{n_hit} duplicate queries cleared "
            f"s_th={s_th} — the recall floor compared (almost) nothing")
    if not (recall_hits >= 0.99):          # NaN fails too
        failures.append(f"recall@1 {recall_hits:.4f} < 0.99")
    if bytes_ratio > 0.30:
        failures.append(f"bytes ratio {bytes_ratio:.3f} > 0.30")
    if enforce_speedup and speedup < speedup_floor:
        failures.append(f"scan speedup {speedup:.2f}x < {speedup_floor}x")
    return section, failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small store/query count for CI")
    ap.add_argument("--n-store", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--quant-rows", type=int, default=None,
                    help="rows for the quantized flat-scan section "
                         "(default 100K full / 8K smoke)")
    ap.add_argument("--quant-speedup-floor", type=float, default=1.4,
                    help="int8-vs-legacy scan throughput floor, enforced "
                         "in full mode (tripwire below the ~2x measured "
                         "at N=100K)")
    ap.add_argument("--pipeline-queries", type=int, default=None,
                    help="mixed-stream size for the pipelined section "
                         "(default 64 full / 24 smoke)")
    ap.add_argument("--pipeline-ratio-floor", type=float, default=0.5,
                    help="hit p50 must be <= this fraction of miss p50 "
                         "through the staged pipeline (enforced always)")
    ap.add_argument("--decode-slots", type=int, default=4,
                    help="persistent continuous-batching decode slots for "
                         "the pipelined section")
    args = ap.parse_args(argv)

    n_store = args.n_store or (2000 if args.smoke else 20000)
    n_q = args.n_queries or (128 if args.smoke else 512)
    B = args.batch
    quant_rows = args.quant_rows or (8000 if args.smoke else 100_000)
    pipe_q = args.pipeline_queries or (24 if args.smoke else 64)

    with tempfile.TemporaryDirectory() as td:
        build_synth_store(td, make_embedder("hash"), n_store)
        cfg = SystemCfg(s_th_run=0.9,
                        batched=BatchedRuntimeCfg(max_batch=B))
        with StorInfer.open(td, cfg) as si:
            tier = tier_of(si.index)
            queries = user_queries(n_q, n_store)

            # warm the jit caches on both paths before timing
            si.query(queries[0])
            si.query_batch(queries[:B])

            # -- sequential: the paper's one-at-a-time race loop -----------
            seq_lat = []
            t0 = time.perf_counter()
            seq_hits = 0
            for q in queries:
                t1 = time.perf_counter()
                r = si.query(q)
                seq_lat.append(time.perf_counter() - t1)
                seq_hits += int(r.hit)
            seq_total = time.perf_counter() - t0
            seq_qps = n_q / seq_total

            # -- batched: microbatches of B through one index dispatch -----
            bat_lat = []
            t0 = time.perf_counter()
            bat_hits = 0
            for lo in range(0, n_q, B):
                chunk = queries[lo:lo + B]
                t1 = time.perf_counter()
                rs = si.query_batch(chunk)
                dt = time.perf_counter() - t1
                bat_lat.extend([dt] * len(chunk))  # each waits its batch
                bat_hits += sum(r.hit for r in rs)
            bat_total = time.perf_counter() - t0
            bat_qps = n_q / bat_total

        assert seq_hits == bat_hits, (seq_hits, bat_hits)
        speedup = bat_qps / seq_qps
        payload = {
            "n_store": n_store, "n_queries": n_q, "batch": B,
            "index_tier": tier, "hit_rate": seq_hits / n_q,
            "sequential": {"qps": seq_qps, **pcts(seq_lat)},
            "batched": {"qps": bat_qps, **pcts(bat_lat)},
            "speedup_qps": speedup,
            "smoke": bool(args.smoke),
        }
        print(f"store={n_store} ({tier})  queries={n_q}  batch={B}")
        print(f"sequential: {seq_qps:8.1f} q/s  "
              f"p50={payload['sequential']['p50_ms']:.2f}ms "
              f"p99={payload['sequential']['p99_ms']:.2f}ms")
        print(f"batched:    {bat_qps:8.1f} q/s  "
              f"p50={payload['batched']['p50_ms']:.2f}ms "
              f"p99={payload['batched']['p99_ms']:.2f}ms")
        print(f"speedup: {speedup:.1f}x (floor 4x)")

    failures = []
    if speedup < 4.0:
        failures.append(
            f"batched speedup {speedup:.1f}x below the 4x floor")

    # hit-latency decoupling through the staged pipeline (floor enforced
    # in smoke mode too — real decode keeps the margin wide)
    payload["pipelined"], pf = bench_pipelined_serving(
        n_store=2000 if args.smoke else 4000, n_q=pipe_q, batch=B,
        s_th=0.9, ratio_floor=args.pipeline_ratio_floor,
        decode_slots=args.decode_slots,
        max_new=8 if args.smoke else 16)
    failures += pf

    # the N>=100K bandwidth effect is what the floor measures; at smoke
    # scale the section still runs (recall + bytes floors enforced) but
    # the throughput ratio is report-only
    payload["quantized_flat"], qf = bench_quantized_flat(
        quant_rows, n_q=max(n_q, 128), batch=B, s_th=0.9,
        speedup_floor=args.quant_speedup_floor,
        enforce_speedup=not args.smoke)
    failures += qf

    out_write("BENCH_batched_serve", payload, root_name="BENCH_serve")
    for f in failures:
        print(f"WARNING: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
