"""Table 2: response quality + hit rate vs runtime threshold (SQuAD).

For each user query: top-1 similarity >= S_th_Run -> the STORED response is
returned; below -> the fallback LLM responds (the oracle-8B responder, the
paper's no-cache baseline). Quality is scored against the gold fact answer
with Unigram F1 / ROUGE-L F1 / BERTScore-proxy. Reference rows: the 8B
responder on every query (upper baseline) and the degraded 1B responder
(lower baseline) — the paper's claim to check: quality(th=0.9) ~ 8B, and
quality(th=0.5) > 1B at ~0.93 hit rate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_setup, hit_stats, out_write
from repro.core import metrics as MX
from repro.core.generator import SyntheticOracleLM, chunk_key

THRESHOLDS = (0.5, 0.7, 0.9)
PAPER = {
    0.5: {"unigram": 0.389, "rouge": 0.404, "bert": 0.308, "hit": 0.930},
    0.7: {"unigram": 0.446, "rouge": 0.463, "bert": 0.353, "hit": 0.690},
    0.9: {"unigram": 0.570, "rouge": 0.586, "bert": 0.458, "hit": 0.225},
    "8b": {"unigram": 0.589, "rouge": 0.598, "bert": 0.439},
    "1b": {"unigram": 0.307, "rouge": 0.332, "bert": 0.305},
}


def _score(preds, refs):
    return {
        "unigram": MX.corpus_mean(MX.unigram_f1, preds, refs),
        "rouge": MX.corpus_mean(MX.rouge_l_f1, preds, refs),
        "bert": MX.corpus_mean(MX.bert_score_f1, preds, refs),
    }


def main():
    setup = build_setup("squad", dedup=True)
    kb, store, user = setup["kb"], setup["store"], setup["user"]
    lm8 = SyntheticOracleLM(kb, quality="8b")
    lm1 = SyntheticOracleLM(kb, quality="1b")
    golds = [f.answer() for _, f in user]
    chunks = {f.doc_id: chunk_key(f.doc_id, kb.doc_text(f.doc_id))
              for _, f in user}

    resp8 = [lm8.answer(q, chunks[f.doc_id]) for q, f in user]
    resp1 = [lm1.answer(q, chunks[f.doc_id]) for q, f in user]

    rows = []
    for th in THRESHOLDS:
        hr, top_rows, scores, _ = hit_stats(setup, th)
        preds = []
        for (q, f), row, sc, fb in zip(user, top_rows, scores, resp8):
            preds.append(store.get_response(int(row)) if sc >= th else fb)
        m = _score(preds, golds)
        rows.append({"s_th_run": th, "hit_rate": hr, **m,
                     "paper": PAPER[th]})
    base8 = _score(resp8, golds)
    base1 = _score(resp1, golds)
    payload = {"rows": rows, "baseline_8b": base8, "baseline_1b": base1,
               "paper_baselines": {"8b": PAPER["8b"], "1b": PAPER["1b"]}}
    out_write("table2_threshold", payload)
    print("name,s_th_run,hit_rate,unigram_f1,rouge_l_f1,bert_f1")
    for r in rows:
        print(f"table2,{r['s_th_run']},{r['hit_rate']:.3f},"
              f"{r['unigram']:.3f},{r['rouge']:.3f},{r['bert']:.3f}")
    print(f"table2,8b_baseline,-,{base8['unigram']:.3f},"
          f"{base8['rouge']:.3f},{base8['bert']:.3f}")
    print(f"table2,1b_baseline,-,{base1['unigram']:.3f},"
          f"{base1['rouge']:.3f},{base1['bert']:.3f}")
    # paper's qualitative claims
    hit_by_th = {r["s_th_run"]: r["hit_rate"] for r in rows}
    assert hit_by_th[0.5] > hit_by_th[0.7] > hit_by_th[0.9]
    q_by_th = {r["s_th_run"]: r["unigram"] for r in rows}
    assert q_by_th[0.9] >= q_by_th[0.5]
    assert q_by_th[0.5] > base1["unigram"] * 0.95, \
        "low-threshold quality should beat the 1B responder"
    return payload


if __name__ == "__main__":
    main()
