"""Whisper-base encoder-decoder backbone [arXiv:2212.04356; unverified].

6L enc + 6L dec, d_model 512, 8 heads (MHA), d_ff 2048, vocab 51865. The conv
audio frontend is a STUB: input_specs() provides precomputed mel-frame
embeddings (B, frames, d_model); see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,                # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    encoder_seq=1500,
    frontend="audio",
    gated_mlp=False,           # whisper uses plain GELU MLP
    mlp_act="gelu",
    rope_kind="none",          # learned/sinusoidal positions; we use sinusoidal
    norm_eps=1e-5,
))
