"""Shared benchmark setup: builds (or loads cached) precomputed stores per
dataset profile x generation mode through the ``StorInfer`` facade,
mirroring the paper's §4 pipeline.

Scale knob: REPRO_BENCH_SCALE env (default 1.0) multiplies store/user-query
counts — the defaults keep `python -m benchmarks.run` to minutes on CPU;
the paper's 150K-pair operating point is reached with scale ~19.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.api import StorInfer, SystemCfg, make_index
from repro.core.generator import GenCfg
from repro.core.kb import build_kb, sample_user_queries
from repro.core.precompute import PrecomputeCfg

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
N_STORE = int(8000 * SCALE)
N_USER = int(2000 * SCALE)
DATASETS = ("squad", "narrativeqa", "triviaqa")

REPO = Path(__file__).resolve().parents[1]
ROOT = REPO / "experiments"
CACHE = ROOT / "bench_cache"
OUT = ROOT / "bench"


def out_write(name: str, payload: dict, root_name: str = None):
    """Write the payload under experiments/bench/; ``root_name`` also drops
    a copy at the repo root (the machine-readable perf-trajectory points —
    BENCH_serve.json / BENCH_precompute.json — that CI uploads)."""
    OUT.mkdir(parents=True, exist_ok=True)
    body = json.dumps(payload, indent=1, default=str)
    (OUT / f"{name}.json").write_text(body)
    if root_name:
        (REPO / f"{root_name}.json").write_text(body)


def _system_cfg(dedup: bool, wave: int) -> SystemCfg:
    # flat (exact) index regardless of store size: the tables report exact
    # hit rates, so the tier choice must not inject IVF approximation
    return SystemCfg(index="flat", cache_index=False,
                     gen=GenCfg(dedup=dedup),
                     precompute=PrecomputeCfg(wave=wave))


def build_setup(dataset: str, dedup: bool, n_store: int = None, seed=0,
                wave: int = 32):
    """Returns dict(kb, emb, store, index, user, gen_stats, system).

    Stores are built through ``StorInfer.build`` (the batched precompute
    pipeline underneath; wave is part of the cache key, and dedup
    decisions are made on store-dtype-rounded similarities, see
    core/precompute.py) — that is what makes REPRO_BENCH_SCALE ~19, the
    paper's 150K-pair operating point, reachable on a CPU box.
    """
    n_store = n_store or N_STORE
    key = (f"{dataset}_{'dedup' if dedup else 'random'}_{n_store}_{seed}"
           f"_w{wave}")
    cache_dir = CACHE / key
    kb = build_kb(dataset, seed=seed)
    cfg = _system_cfg(dedup, wave)
    # gen_stats.json is written only on completion; the pipeline
    # checkpoints manifest.json mid-build, so manifest-exists alone would
    # mistake an interrupted build for a finished cache
    if (cache_dir / "gen_stats.json").exists():
        system = StorInfer.open(cache_dir, cfg)
        stats = json.loads((cache_dir / "gen_stats.json").read_text())
    else:
        system = StorInfer.build(kb, cfg, cache_dir, n_pairs=n_store,
                                 seed=seed + 11)
        st = system.build_stats
        stats = {"generated": st.generated, "discarded": st.discarded,
                 "seconds": st.seconds,
                 "max_wave_seconds": st.max_wave_seconds,
                 "sec_per_pair": st.seconds / max(st.generated, 1),
                 "temp_final": st.temp_final}
        (cache_dir / "gen_stats.json").write_text(json.dumps(stats))
    user = sample_user_queries(kb, N_USER, seed=seed + 77)
    return {"kb": kb, "emb": system.embedder, "store": system.store,
            "index": system.index, "user": user, "gen_stats": stats,
            "system": system}


def hit_stats(setup, s_th_run: float, n_prefix: int = None):
    """Search every user query; returns (hit_rate, rows, scores,
    search_seconds_per_query)."""
    emb, index, store = setup["emb"], setup["index"], setup["store"]
    if n_prefix is not None:
        index = make_index("flat", store.embeddings()[:n_prefix])
    ue = emb.encode([q for q, _ in setup["user"]])
    t0 = time.perf_counter()
    v, i = index.search(ue, 1)
    search_s = (time.perf_counter() - t0) / len(ue)
    hits = v[:, 0] >= s_th_run
    return float(hits.mean()), i[:, 0], v[:, 0], search_s
