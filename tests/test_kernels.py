"""Pallas kernels vs pure-jnp oracles: interpret-mode allclose across
shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade to a fixed deterministic sample
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# mips_topk
# ---------------------------------------------------------------------------


# edge shapes by design: Q=1, k == tile_n, N < tile_n, D not lane-aligned
@pytest.mark.parametrize("Q,N,D,k,tile", [
    (4, 100, 16, 5, 32),
    (8, 512, 384, 10, 128),
    (1, 33, 24, 3, 32),        # Q=1
    (16, 1024, 64, 16, 512),
    (2, 300, 100, 32, 32),     # k == tile_n, D not 128-aligned
    (1, 64, 48, 64, 512),      # N < tile_n, k == N
    (3, 200, 384, 1, 128),     # k=1 (the serving hot path)
])
def test_mips_topk_matches_ref(Q, N, D, k, tile):
    rng = np.random.default_rng(Q + N)
    q = _rand(rng, (Q, D))
    x = _rand(rng, (N, D))
    v, i = ops.mips_topk(q, x, k, tile)
    vr, ir = ref.mips_topk_ref(q, x, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-5,
                               atol=1e-5)
    # indices may differ on exact ties; compare the scores they select
    sel = np.take_along_axis(np.asarray(q @ x.T), np.asarray(i), axis=1)
    np.testing.assert_allclose(sel, np.asarray(vr), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 7), st.integers(5, 90), st.integers(4, 40),
       st.integers(1, 5))
def test_mips_topk_property(Q, N, D, k):
    rng = np.random.default_rng(Q * 1000 + N)
    q = _rand(rng, (Q, D))
    x = _rand(rng, (N, D))
    v, i = ops.mips_topk(q, x, min(k, N), 32)
    vr, _ = ref.mips_topk_ref(q, x, min(k, N))
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-5,
                               atol=1e-5)
    # all returned indices are valid rows
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < N).all()


def test_tile_topk_exact_with_ties():
    """The shared streaming tile top-k is EXACT including its tie-break
    (value desc, index asc) — bitwise against the numpy reference."""
    from repro.kernels.mips_topk import tile_topk
    rng = np.random.default_rng(3)
    for Q, T, k in [(4, 512, 10), (1, 128, 1), (3, 384, 16), (2, 256, 5),
                    (5, 512, 100), (2, 64, 64), (2, 100, 7)]:
        s = rng.normal(size=(Q, T)).astype(np.float32)
        s[:, ::7] = s[:, 0:1]                  # force heavy value ties
        v, i = tile_topk(jnp.asarray(s), k)
        vr, ir = ref.topk_by_value_ref(s, k)
        assert np.array_equal(np.asarray(v), vr), (Q, T, k)
        assert np.array_equal(np.asarray(i), ir), (Q, T, k)


def _quant(a):
    from repro.core.store import quantize_rows
    return quantize_rows(a)


# same edge-shape sweep as the fp32 kernel; validation is BIT-FOR-BIT
@pytest.mark.parametrize("Q,N,D,k,tile", [
    (4, 100, 16, 5, 32),
    (8, 512, 384, 10, 128),
    (1, 33, 24, 3, 32),        # Q=1
    (16, 1024, 64, 16, 512),
    (2, 300, 100, 32, 32),     # k == tile_n, D not 128-aligned
    (1, 64, 48, 64, 512),      # N < tile_n, k == N
    (2, 700, 384, 1, 512),     # k=1 (the serving hot path)
])
def test_mips_topk_int8_bit_for_bit(Q, N, D, k, tile):
    rng = np.random.default_rng(Q * 7 + N)
    q8, qs = _quant(rng.normal(size=(Q, D)).astype(np.float32))
    x8, xs = _quant(rng.normal(size=(N, D)).astype(np.float32))
    v, i = ops.mips_topk_int8(jnp.asarray(q8), jnp.asarray(qs),
                              jnp.asarray(x8), jnp.asarray(xs), k, tile)
    vr, ir = ref.mips_topk_int8_ref(q8, qs, x8, xs, k)
    assert np.array_equal(np.asarray(v), vr)
    assert np.array_equal(np.asarray(i), ir)


def test_mips_topk_int8_recall_parity():
    """int8-vs-fp32 recall@1 >= 0.99 on the serving workload (queries are
    near-duplicates of stored rows — the regime the threshold race uses)."""
    rng = np.random.default_rng(11)
    N, D, Q = 5000, 384, 256
    x = rng.normal(size=(N, D)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    q = x[rng.integers(0, N, Q)] \
        + 0.05 * rng.normal(size=(Q, D)).astype(np.float32)
    q = (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)
    _, i32 = ref.mips_topk_ref(jnp.asarray(q), jnp.asarray(x), 1)
    q8, qs = _quant(q)
    x8, xs = _quant(x)
    _, i8 = ops.mips_topk_int8(jnp.asarray(q8), jnp.asarray(qs),
                               jnp.asarray(x8), jnp.asarray(xs), 1)
    recall = (np.asarray(i8)[:, 0] == np.asarray(i32)[:, 0]).mean()
    assert recall >= 0.99, recall


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,T,Hq,Hkv,D,causal,dtype", [
    (2, 32, 32, 4, 2, 16, True, np.float32),
    (1, 40, 40, 8, 8, 32, True, np.float32),
    (2, 17, 17, 6, 2, 8, True, np.float32),
    (2, 24, 24, 4, 4, 16, False, np.float32),
    (1, 64, 64, 4, 1, 64, True, np.float32),
])
def test_flash_attention_matches_ref(B, S, T, Hq, Hkv, D, causal, dtype):
    rng = np.random.default_rng(S + Hq)
    q = _rand(rng, (B, S, Hq, D), dtype)
    k = _rand(rng, (B, T, Hkv, D), dtype)
    v = _rand(rng, (B, T, Hkv, D), dtype)
    o = ops.flash_attention(q, k, v, causal, 16, 16)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o_ref = jnp.transpose(ref.attention_ref(qt, kt, vt, causal=causal),
                          (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-4,
                               atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 32, 4, 16)).astype(jnp.bfloat16)
    k = _rand(rng, (2, 32, 2, 16)).astype(jnp.bfloat16)
    v = _rand(rng, (2, 32, 2, 16)).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, True, 16, 16)
    qt, kt, vt = (jnp.transpose(a, (0, 2, 1, 3)) for a in (q, k, v))
    o_ref = jnp.transpose(ref.attention_ref(qt, kt, vt), (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,Hq,Hkv,D,ns", [
    (2, 64, 4, 2, 16, 4),
    (1, 100, 8, 8, 32, 8),
    (3, 33, 6, 2, 8, 2),
    (2, 128, 16, 4, 64, 16),
])
def test_decode_attention_matches_ref(B, T, Hq, Hkv, D, ns):
    rng = np.random.default_rng(T + Hq)
    q = _rand(rng, (B, Hq, D))
    k = _rand(rng, (B, T, Hkv, D))
    v = _rand(rng, (B, T, Hkv, D))
    lengths = jnp.asarray(rng.integers(0, T, (B,)), jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, ns)
    o_ref = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(4, 70), st.integers(1, 3),
       st.integers(1, 4))
def test_decode_attention_property(B, T, Hkv, G):
    Hq, D = Hkv * G, 8
    rng = np.random.default_rng(B * 100 + T)
    q = _rand(rng, (B, Hq, D))
    k = _rand(rng, (B, T, Hkv, D))
    v = _rand(rng, (B, T, Hkv, D))
    lengths = jnp.asarray(rng.integers(0, T, (B,)), jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, 4)
    o_ref = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=1e-3,
                               atol=1e-3)


def test_decode_attention_equals_model_decode_math():
    """The kernel's contract matches the seq-sharded shard_map combine."""
    from repro.kernels.decode_attention import (decode_attention_pallas,
                                                combine_splits)
    rng = np.random.default_rng(5)
    q = _rand(rng, (2, 4, 16))
    k = _rand(rng, (2, 64, 2, 16))
    v = _rand(rng, (2, 64, 2, 16))
    lengths = jnp.asarray([10, 63], jnp.int32)
    o1 = combine_splits(*decode_attention_pallas(q, k, v, lengths,
                                                 n_splits=4))
    o2 = combine_splits(*decode_attention_pallas(q, k, v, lengths,
                                                 n_splits=16))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-5)
