"""Pallas TPU kernel: tiled MIPS + per-tile top-k (the StorInfer hot spot).

The paper scans a DiskANN graph on CPU; on TPU the same search is a matmul
(DESIGN.md §3): the store shard streams through VMEM in (TILE_N, D) blocks,
each block scoring against the resident query block on the MXU, followed by
an on-chip streaming top-k over the tile (``tile_topk``, shared with the
int8 kernel in mips_topk_int8.py). The host-side combine (ops.py) reduces
the (n_tiles, Q, K) candidates with one final lax.top_k — O(n_tiles * K)
per query, independent of N.

Tiling:
  q   : (Q, D)       resident in VMEM for the whole grid (Q <= ~1024)
  x   : (TILE_N, D)  one store tile per grid step (128-aligned)
  out : (Q, K) vals + (Q, K) idx per tile, written to grid slot i

VMEM working set per step ~= Q*D + TILE_N*D + Q*TILE_N floats; defaults
(Q<=256, TILE_N=512, D=384) ~ 1 MB — far under the ~16 MB v5e VMEM budget;
the MXU sees (Q x D) @ (D x TILE_N) with D padded to a lane multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30
# tile_topk pads candidate indices with this sentinel; it must sort after
# every real (< 2^24) row id under the (value desc, index asc) order
_IDX_PAD = 2 ** 30


def _ge(av, ai, bv, bi):
    """Strict total order used everywhere in the tile top-k: value
    descending, index ascending on value ties. Matching the numpy
    reference's tie-break exactly is what makes the int8 kernel's
    bit-for-bit validation possible."""
    return (av > bv) | ((av == bv) & (ai <= bi))


def _chunk_topk(s, k, col0):
    """Exact top-k of one (Q, c) score chunk by k masked argmax passes,
    emitted in (value desc, index asc) order. ``col0`` is the chunk's
    first column; returned indices are tile-local."""
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    vals, idxs = [], []
    for _ in range(k):
        m = jnp.max(s, axis=1)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)   # first max: lowest idx
        vals.append(m)
        idxs.append(a + col0)
        s = jnp.where(cols == a[:, None], NEG, s)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def _bitonic_merge_desc(v, i):
    """Sort a bitonic (Q, m) candidate list descending (m a power of two):
    log2(m) compare-exchange stages, each one reshape + min/max — no
    gathers, so it lowers cleanly on the VPU."""
    m = v.shape[-1]
    stride = m // 2
    while stride >= 1:
        shp = v.shape
        v4 = v.reshape(shp[:-1] + (m // (2 * stride), 2, stride))
        i4 = i.reshape(v4.shape)
        av, bv = v4[..., 0, :], v4[..., 1, :]
        ai, bi = i4[..., 0, :], i4[..., 1, :]
        ge = _ge(av, ai, bv, bi)
        v = jnp.stack([jnp.where(ge, av, bv), jnp.where(ge, bv, av)],
                      axis=-2).reshape(shp)
        i = jnp.stack([jnp.where(ge, ai, bi), jnp.where(ge, bi, ai)],
                      axis=-2).reshape(shp)
        stride //= 2
    return v, i


def _merge_desc(rv, ri, cv, ci):
    """Merge two descending-sorted (Q, m) candidate lists into the top-m
    of their union. Max-pairing rv[j] against reversed cv picks the top-m
    multiset in one element-wise pass (the first stage of a bitonic merge
    of [rv ; reverse(cv)]); the result is bitonic, so log2(m) further
    stages restore descending order."""
    cv_r, ci_r = cv[..., ::-1], ci[..., ::-1]
    take = _ge(rv, ri, cv_r, ci_r)
    v = jnp.where(take, rv, cv_r)
    i = jnp.where(take, ri, ci_r)
    return _bitonic_merge_desc(v, i)


def tile_topk(s, k, *, chunk=128):
    """Exact top-k along the last axis of ``s`` (Q, T), ordered by
    (value desc, index asc). Returns (vals (Q, k), idx (Q, k) int32).

    Replaces the old k-pass masked argmax over the FULL tile (which also
    rewrote the whole (Q, T) block with a masking ``where`` every pass —
    2k full-tile traversals): the tile is streamed once in lane-width
    chunks, each chunk's top-k is selected inside that small hot block,
    and the running candidate list is folded in with an O(k log k)
    bitonic max-pairing merge on (Q, k). The (Q, T) score block is read
    once and never written back.
    """
    Q, T = s.shape
    if k > T:
        raise ValueError(f"tile_topk: k={k} exceeds tile width {T}")
    c = min(chunk, T)
    if k > c or T % c:
        c = T                      # rare big-k / ragged tile: single chunk
    if c == T:                     # one chunk: plain selection, no merge
        return _chunk_topk(s, k, 0)
    # pad the candidate lists to a power of two for the merge network
    k2 = 1
    while k2 < k:
        k2 *= 2
    pad_v = jnp.full((Q, k2 - k), NEG, s.dtype)
    pad_i = jnp.full((Q, k2 - k), _IDX_PAD, jnp.int32)

    def padded(v, i):
        if k2 == k:
            return v, i
        return (jnp.concatenate([v, pad_v], axis=1),
                jnp.concatenate([i, pad_i], axis=1))

    rv = ri = None
    for lo in range(0, T, c):
        cv, ci = padded(*_chunk_topk(s[:, lo:lo + c], k, lo))
        if rv is None:
            rv, ri = cv, ci
        else:
            rv, ri = _merge_desc(rv, ri, cv, ci)
    return rv[:, :k], ri[:, :k]


def _mips_kernel(q_ref, x_ref, vals_ref, idx_ref, *, k, tile_n, n_real):
    i = pl.program_id(0)
    q = q_ref[...]                                    # (Q, D)
    x = x_ref[...]                                    # (TILE_N, D)
    s = jnp.dot(q, x.T, preferred_element_type=jnp.float32)  # (Q, TILE_N)
    # mask padded store rows (beyond n_real)
    row_global = i * tile_n + jax.lax.broadcasted_iota(jnp.int32,
                                                       s.shape, 1)
    s = jnp.where(row_global < n_real, s, NEG)
    vals, idx = tile_topk(s, k)
    vals_ref[0] = vals
    idx_ref[0] = idx


def mips_topk_pallas(q, x, k, *, tile_n=512, interpret=True):
    """q: (Q, D) f32; x: (N, D) float (f32/f16/bf16 — the MXU dot upcasts
    once in-register, so fp16 shards never materialize an fp32 copy).
    Returns per-tile candidates (vals (nt, Q, k), idx-global (nt, Q, k))."""
    Q, D = q.shape
    N = x.shape[0]
    nt = -(-N // tile_n)
    N_pad = nt * tile_n
    if N_pad != N:
        x = jnp.pad(x, ((0, N_pad - N), (0, 0)))
    Dp = -(-D // 128) * 128                           # lane alignment
    if Dp != D:
        q = jnp.pad(q, ((0, 0), (0, Dp - D)))
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))

    kernel = functools.partial(_mips_kernel, k=k, tile_n=tile_n, n_real=N)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((Q, Dp), lambda i: (0, 0)),        # q resident
            pl.BlockSpec((tile_n, Dp), lambda i: (i, 0)),   # x streamed
        ],
        out_specs=[
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt, Q, k), jnp.float32),
            jax.ShapeDtypeStruct((nt, Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
    # per-tile local idx -> global row ids
    offs = (jnp.arange(nt, dtype=jnp.int32) * tile_n)[:, None, None]
    return vals, idx + offs
