"""Batched, resumable offline precompute pipeline (§3.2/§3.3 at paper scale).

The paper's headline artifact is an offline-generated store of 150K
deduplicated (query, response) pairs. The sequential reference loop
(``repro.core.generator.QueryGenerator``) cannot reach that scale in
reasonable time: one ``embedder.encode`` call per candidate and an O(N)
dense dedup scan that re-concatenates the whole embedding matrix on every
accept. This pipeline keeps the paper's semantics — adaptive query masking
and adaptive sampling, per knowledge chunk — and restructures the loop
around waves:

* **Wave generation** — W candidates are drawn per step, round-robin
  across KB chunks, each against its chunk's current temperature and the
  wave-start mask set.
* **Batched embedding** — one ``embedder.encode`` call per wave.
* **Index-backed dedup** — the wave is scored against an
  ``IncrementalIndex`` (flat buffer below the tier boundary, IVF with
  assign-to-nearest-centroid appends above it) instead of the quadratic
  matrix scan; wave-internal collisions are discarded too, via the wave's
  Gram matrix.
* **Checkpointed builds** — generator state (per-chunk temperatures, the
  recent-mask ring, the RNG bit-generator state, the chunk cursor and
  attempt/wave counters) is written into the store manifest at every
  checkpoint, so a killed build resumes where it stopped and — because the
  dedup index rebuild and the wave schedule are deterministic — produces a
  store byte-identical to an uninterrupted run.

At ``wave=1`` the pipeline reproduces the sequential generator exactly —
same RNG stream, same accept/discard decisions — when the dedup dtype
matches (store-free runs, or a float32 store; tests pin that
equivalence). At larger waves the semantics differ only in visibility:
the W candidates of one wave are generated against the same wave-start
state, so they cannot see each other in the mask set (their collisions
are still caught by the Gram check).

Dedup similarities are computed on embeddings round-tripped through the
store dtype (``store.roundtrip_dtype`` — float16 by default, symmetric
per-row int8 quantize/dequantize for ``emb_dtype="int8"``): an
uninterrupted run and a resumed run (which rebuilds its dedup index from
the store's own shards) then see bit-identical similarity scores — with
raw float32 the two could disagree on candidates sitting exactly at the
0.99 threshold. The flip side: with a narrowed store dtype the pipeline's
accept/discard decisions can in principle differ from the raw-float32
sequential generator for candidates straddling the threshold under one
rounding but not the other.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.generator import GenCfg, QueryLM, masked_for_chunk
from repro.core.index import FLAT_MAX_ROWS, IncrementalIndex
from repro.core.store import roundtrip_dtype

STATE_KEY = "gen_state"
STATE_VERSION = 1


class BuildKilled(RuntimeError):
    """Raised by the test/bench hook that simulates a killed build."""


def chunks_digest(chunks: Sequence[str]) -> int:
    """Content digest of the chunk sequence: resuming against a different
    KB (seed, dataset, doc set) must fail loudly, not splice two worlds
    into one store — the chunk COUNT alone cannot tell them apart."""
    h = 0
    for c in chunks:
        h = zlib.crc32(c.encode("utf-8"), h)
    return h


@dataclasses.dataclass
class PrecomputeCfg:
    wave: int = 32                 # candidates per step (W)
    checkpoint_every: int = 64     # waves between flush + state checkpoint
    flat_max_rows: int = FLAT_MAX_ROWS   # dedup-index tier boundary
    background_recluster: bool = False   # IVF refits in a thread (faster,
    #                                      gives up resume determinism)
    max_attempts_factor: int = 20  # attempts cap = factor*n_target + 100


@dataclasses.dataclass
class PrecomputeStats:
    generated: int = 0             # rows accepted by THIS run
    discarded: int = 0             # candidates discarded by THIS run
    seconds: float = 0.0           # cumulative build seconds (incl. any
    #                                killed prefix this run resumed)
    run_seconds: float = 0.0       # wall-clock of THIS run only
    waves: int = 0
    max_wave_seconds: float = 0.0
    temp_final: float = 0.0
    resumed_rows: int = 0          # rows already in the store at start
    index_mode: str = "flat"       # dedup index tier at end of run

    @property
    def pairs_per_sec(self) -> float:
        """This run's throughput (resumed prefixes excluded on both
        sides of the division)."""
        return self.generated / self.run_seconds if self.run_seconds \
            else 0.0


class PrecomputePipeline:
    """Drives a QueryLM over KB chunks into a store, W candidates at a time.

    ``run`` mirrors ``QueryGenerator.generate``'s contract — returns
    ``(queries, responses, embeddings, stats)`` for the rows accepted by
    THIS run (a resumed run returns only its continuation) and streams
    accepted rows into ``store`` as it goes.
    """

    def __init__(self, lm: QueryLM, embedder, tokenizer,
                 gen_cfg: GenCfg = None, cfg: PrecomputeCfg = None):
        self.lm = lm
        self.embedder = embedder
        self.tok = tokenizer
        self.gen_cfg = gen_cfg or GenCfg()
        self.cfg = cfg or PrecomputeCfg()

    # -- checkpoint state -----------------------------------------------------
    def _config_sig(self) -> dict:
        """Everything besides the chunks that changes what rows a build
        produces: the embedder identity (resuming a hash-embedded store
        with a neural encoder would splice two embedding spaces into one
        index), the generation config, and the checkpoint cadence (it
        sets the flush schedule the byte-identity guarantee replays)."""
        return {
            "embedder": type(self.embedder).__name__,
            "dim": int(getattr(self.embedder, "dim", 384)),
            "checkpoint_every": self.cfg.checkpoint_every,
            "gen": dataclasses.asdict(self.gen_cfg),
        }

    def _capture_state(self, digest, rng, temps, recent, ci, attempts,
                       waves, generated, discarded, elapsed) -> dict:
        g = self.gen_cfg
        return {
            "version": STATE_VERSION,
            "wave": self.cfg.wave,
            "chunks_digest": digest,
            "config": self._config_sig(),
            "n_chunks": len(temps),
            "temps": [float(t) for t in temps],
            # only the tail the masker can ever read (the "recent ring")
            "recent": list(recent[-g.mask_recent:]),
            "ci": ci, "attempts": attempts, "waves": waves,
            "generated": generated, "discarded": discarded,
            "elapsed": elapsed,
            "rng_state": rng.bit_generator.state,
        }

    def _checkpoint(self, store, state: dict):
        store.manifest_extra[STATE_KEY] = state
        store.flush()

    # -- main loop ------------------------------------------------------------
    def run(self, chunks: Sequence[str], n_target: int, *, store=None,
            seed: int = 0, resume: bool = True,
            on_wave: Optional[Callable] = None,
            _kill_after_waves: Optional[int] = None
            ) -> Tuple[List[str], List[str], np.ndarray, PrecomputeStats]:
        g, cfg = self.gen_cfg, self.cfg
        n_chunks = len(chunks)
        store_dtype = np.dtype(store.emb_dtype) if store is not None \
            else np.dtype(np.float32)

        digest = chunks_digest(chunks)
        state = None
        if store is not None and resume:
            state = store.manifest_extra.get(STATE_KEY)
        if state is not None:
            if state["n_chunks"] != n_chunks:
                raise ValueError(
                    f"checkpoint was built over {state['n_chunks']} chunks, "
                    f"got {n_chunks}: refusing to resume")
            if state.get("chunks_digest") != digest:
                raise ValueError(
                    "checkpoint was built over DIFFERENT chunk contents "
                    "(another KB seed/dataset/doc set): refusing to splice "
                    "two corpora into one store")
            sig = self._config_sig()
            if state.get("config") != sig:
                diff = {k for k in sig
                        if state.get("config", {}).get(k) != sig[k]}
                raise ValueError(
                    f"checkpoint was built with different {sorted(diff)} "
                    "(embedder/generation config/checkpoint cadence): "
                    "refusing to resume with mismatched settings")
            if state["wave"] != cfg.wave:
                raise ValueError(
                    f"checkpoint used wave={state['wave']}, got {cfg.wave}: "
                    "resume determinism requires the same wave size")
            if state["generated"] != store.count:
                raise ValueError(
                    f"checkpoint says {state['generated']} rows but store "
                    f"has {store.count}: store was modified outside the "
                    "pipeline")
            rng = np.random.default_rng()
            rng.bit_generator.state = state["rng_state"]
            temps = list(state["temps"])
            recent = list(state["recent"])
            ci, attempts = state["ci"], state["attempts"]
            waves = state["waves"]
            generated, discarded = state["generated"], state["discarded"]
            elapsed_prior = state["elapsed"]
        else:
            if store is not None and store.count:
                raise ValueError(
                    f"store already holds {store.count} rows but carries no "
                    "pipeline checkpoint — it was not built by this "
                    "pipeline and cannot be resumed; use a fresh directory")
            rng = np.random.default_rng(seed)
            temps = [g.temp0] * n_chunks
            recent = []
            ci = attempts = waves = generated = discarded = 0
            elapsed_prior = 0.0

        stats = PrecomputeStats(resumed_rows=generated)
        index = IncrementalIndex(
            getattr(self.embedder, "dim", 384),
            flat_max_rows=cfg.flat_max_rows,
            background=cfg.background_recluster) if g.dedup else None
        if index is not None and store is not None and store.count:
            # rebuild the dedup index from the store's own shards: the
            # float16 round-trip makes the rebuilt scores bit-identical to
            # the in-run ones, and the deterministic refit thresholds make
            # the IVF state independent of shard batching
            for shard in store.embeddings().iter_shards():
                index.add(np.asarray(shard, np.float32))

        out_q: List[str] = []
        out_r: List[str] = []
        out_e: List[np.ndarray] = []
        max_attempts = n_target * cfg.max_attempts_factor + 100
        t_start = time.perf_counter()
        waves_this_run = 0

        while generated < n_target and attempts < max_attempts:
            t0 = time.perf_counter()
            w = min(cfg.wave, max_attempts - attempts)
            # 1. wave generation: W candidates against wave-start state
            idxs, qs = [], []
            for j in range(w):
                k = (ci + j) % n_chunks
                chunk = chunks[k]
                masked = masked_for_chunk(self.tok, g, recent, chunk) \
                    if g.dedup else []
                temp = temps[k] if g.dedup else g.temp0
                qs.append(self.lm.generate_query(chunk, masked, temp, rng))
                idxs.append(k)
            ci += w
            attempts += w
            # 2. one embedding batch per wave
            E = np.asarray(self.embedder.encode(qs), np.float32)
            Ed = roundtrip_dtype(E, store_dtype)
            # 3. index-backed dedup + wave-internal Gram check
            if index is not None and len(index):
                base = index.max_sim(Ed)
            else:
                base = np.full(w, -np.inf, np.float32)
            accepted: List[int] = []
            acc_q: List[str] = []
            acc_r: List[str] = []
            for j in range(w):
                if generated >= n_target:
                    break            # target hit mid-wave: drop the tail
                sim = float(base[j])
                if g.dedup and accepted:
                    sim = max(sim, float(np.max(Ed[accepted] @ Ed[j])))
                if g.dedup and sim >= g.s_th_gen:
                    discarded += 1
                    stats.discarded += 1
                    # adaptive sampling: bump this chunk's temperature
                    temps[idxs[j]] = min(temps[idxs[j]] + g.temp_step,
                                         g.temp_max)
                    recent.append(qs[j])
                    continue
                acc_q.append(qs[j])
                acc_r.append(self.lm.answer(qs[j], chunks[idxs[j]]))
                recent.append(qs[j])
                accepted.append(j)
                generated += 1
                stats.generated += 1
            waves += 1
            waves_this_run += 1
            if len(recent) > g.mask_recent:
                recent = recent[-g.mask_recent:]
            if accepted:
                if index is not None:
                    index.add(Ed[accepted])
                if store is not None:
                    store.add_batch(E[accepted], acc_q, acc_r)
                out_q.extend(acc_q)
                out_r.extend(acc_r)
                out_e.append(E[accepted])
            stats.max_wave_seconds = max(stats.max_wave_seconds,
                                         time.perf_counter() - t0)
            if on_wave is not None:
                on_wave(waves, generated, discarded,
                        index.mode if index is not None else "off")
            if (_kill_after_waves is not None
                    and waves_this_run >= _kill_after_waves):
                raise BuildKilled(f"killed after {waves_this_run} waves")
            if store is not None and waves % cfg.checkpoint_every == 0:
                self._checkpoint(store, self._capture_state(
                    digest, rng, temps, recent, ci, attempts, waves,
                    generated, discarded,
                    elapsed_prior + time.perf_counter() - t_start))

        if index is not None:
            index.drain()
            stats.index_mode = index.mode
        stats.waves = waves_this_run
        stats.run_seconds = time.perf_counter() - t_start
        stats.seconds = elapsed_prior + stats.run_seconds
        stats.temp_final = max(temps) if temps else g.temp0
        if store is not None:
            self._checkpoint(store, self._capture_state(
                digest, rng, temps, recent, ci, attempts, waves, generated,
                discarded, stats.seconds))
        emb_out = (np.concatenate(out_e, axis=0) if out_e
                   else np.zeros((0, getattr(self.embedder, "dim", 384)),
                                 np.float32))
        return out_q, out_r, emb_out, stats
