"""Benchmark driver: one module per paper table/figure + the roofline
aggregation. CSV on stdout; JSON artifacts in experiments/bench/.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig3 table1
  REPRO_BENCH_SCALE=4 ... (bigger stores; paper scale ~19)
"""
from __future__ import annotations

import sys
import time

from benchmarks import (bench_batched_serve, fig3_latency, fig4_scaling,
                        gen_cost, table1_hitrate, table2_threshold, roofline)

BENCHES = {
    "fig3": fig3_latency.main,
    "table1": table1_hitrate.main,
    "table2": table2_threshold.main,
    "fig4": fig4_scaling.main,
    "gen_cost": gen_cost.main,
    "roofline": roofline.main,
    "batched_serve": lambda: bench_batched_serve.main([]),
}


def main(argv=None):
    names = (argv or sys.argv[1:]) or list(BENCHES)
    for n in names:
        t0 = time.time()
        print(f"# === {n} ===")
        BENCHES[n]()
        print(f"# {n} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
