from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, get_config,
                                list_configs, reduced, register)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config",
           "list_configs", "reduced", "register"]
