"""StorInfer reproduction: precomputed query storage for LLM inference.

Public API — one front door for the whole system:

    from repro import StorInfer, SystemCfg

    kb = build_kb("squad", n_docs=25)
    with StorInfer.build(kb, SystemCfg(), "runs/demo", n_pairs=1500) as si:
        print(si.query("what is the height of aurora bridge?"))

Everything below is re-exported lazily from ``repro.api`` (so importing
a leaf module like ``repro.core.tokenizer`` never pays the JAX import).
The underlying subsystems stay importable at their original paths
(``repro.core.*``, ``repro.serving.*``, ...) — the facade composes them,
it does not hide them.
"""
from __future__ import annotations

_API_EXPORTS = (
    "StorInfer", "SystemCfg", "EngineCfg", "SystemStats",
    "QueryResult", "RuntimeStats",
    "EmbedderProtocol", "IndexProtocol", "IndexCaps", "index_caps",
    "register_embedder", "register_index",
    "make_embedder", "make_index", "make_pipeline", "tier_of",
)

__all__ = list(_API_EXPORTS)


def __getattr__(name):
    if name in _API_EXPORTS:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_EXPORTS))
