"""Scan-aware cost accounting.

XLA's ``compiled.cost_analysis()`` visits a while/scan body ONCE (verified:
a 4-step scan of matmuls reports 1/4 the unrolled FLOPs), so for programs
that scan over layers / attention tiles it undercounts by the trip count.
This module walks the JAXPR instead, multiplying sub-jaxpr costs by scan
lengths — exact FLOP counts for arbitrary nesting.

Bytes are a post-fusion HBM-traffic MODEL (not a measurement) with
PROVENANCE tracking:

* top-level jaxpr inputs (params, batch, caches) are HBM-resident; that
  provenance flows through scan consts/xs (weights re-read every
  iteration — real), while scan CARRIES are VMEM-resident (flash attention
  (o,m,l) states are not HBM traffic);
* gather / dynamic-slice count their OUTPUT bytes (HBM -> VMEM tile
  streaming, e.g. flash KV re-reads per q-block); scatter /
  dynamic-update-slice count the UPDATE bytes (in-place cache writes);
* tensor contractions count HBM operands + their result once; locally
  produced small operands (attention probabilities between the two flash
  matmuls) are VMEM-resident and free — without this the model "charges"
  the full S x T probability tensor to HBM (measured 5-10x overcount on
  32k prefill);
* elementwise / reshape / reduce ops are fused (zero bytes).

Collective bytes are NOT derived here (GSPMD inserts collectives after
jaxpr level); see ``launch.hlo_collectives``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Set

import jax
import jax.extend.core as jexc
import numpy as np

ELEMENTWISE_1 = {
    "add", "sub", "mul", "div", "max", "min", "and", "or", "xor", "not",
    "neg", "abs", "sign", "floor", "ceil", "round", "select_n", "clamp",
    "pow", "rem", "atan2", "nextafter",
}
ELEMENTWISE_TRANS = {
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sqrt", "rsqrt",
    "sin", "cos", "tan", "erf", "erfc", "exp2", "cbrt", "square",
}
REDUCES = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce",
           "reduce_precision", "cumsum", "cumlogsumexp", "cummax", "cummin",
           "cumprod"}
ZERO_COST = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "slice", "squeeze", "expand_dims", "rev", "iota", "stop_gradient",
    "copy", "bitcast_convert_type", "eq", "ne",
    "lt", "le", "gt", "ge", "is_finite", "integer_pow",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "population_count", "clz", "real", "imag", "complex", "conj",
    "device_put", "sharding_constraint", "split", "concatenate", "pad",
    "rng_bit_generator", "random_seed", "random_bits", "random_wrap",
    "random_fold_in", "zeros_like", "optimization_barrier",
}
COLLECTIVES = {"psum", "pmax", "pmin", "all_to_all", "all_gather",
               "ppermute", "axis_index", "reduce_scatter", "pmean",
               "psum_invariant"}
GATHERS = {"gather", "dynamic_slice", "take"}
SCATTERS = {"scatter", "scatter-add", "scatter_add", "dynamic_update_slice",
            "scatter_max", "scatter_min", "scatter_mul"}


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_size(v) -> int:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2 * int(np.prod(out.shape, dtype=np.int64)) * k


def _sub_jaxprs(params) -> list:
    subs = []
    for v in params.values():
        if isinstance(v, jexc.ClosedJaxpr):
            subs.append(v.jaxpr)
        elif isinstance(v, jexc.Jaxpr):
            subs.append(v)
        elif isinstance(v, (tuple, list)):
            for u in v:
                if isinstance(u, jexc.ClosedJaxpr):
                    subs.append(u.jaxpr)
                elif isinstance(u, jexc.Jaxpr):
                    subs.append(u)
    return subs


def _is_hbm(v, hbm: Set[int]) -> bool:
    return id(v) in hbm or isinstance(v, jexc.Literal)


def jaxpr_costs(jaxpr, hbm: Set[int] = None, _depth=0) -> Dict[str, Any]:
    """{"flops","bytes","warnings"} for one jaxpr (global shapes).

    ``hbm``: ids of in-scope Vars that live in HBM (jaxpr inputs and their
    descendants through container calls). Dot results count once; locally
    produced dot operands are VMEM-free.
    """
    if hbm is None:  # top level: all inputs + consts are HBM-resident
        hbm = {id(v) for v in jaxpr.invars} | \
              {id(v) for v in jaxpr.constvars}
    flops = 0.0
    bytes_ = 0.0
    warnings = []

    def recurse(eqn, mult=1.0, carry_local=0):
        nonlocal flops, bytes_, warnings
        for sub in _sub_jaxprs(eqn.params):
            sub_hbm = set()
            n_outer = len(eqn.invars)
            # positional mapping outer operand -> body invar where lengths
            # line up (scan: [consts, carry, xs]; pjit/custom: 1:1)
            n_body = len(sub.invars) + len(sub.constvars)
            operands = list(eqn.invars)
            body_vars = list(sub.constvars) + list(sub.invars)
            if len(operands) == len(body_vars):
                for o, b in zip(operands, body_vars):
                    if _is_hbm(o, hbm):
                        sub_hbm.add(id(b))
            else:
                # unknown layout: HBM-ness by size (>= 64 MB global)
                for b in body_vars:
                    if _aval_bytes(b) >= 64e6:
                        sub_hbm.add(id(b))
            if carry_local:
                # scan body: invars [consts..., carry..., xs...] — carries
                # are VMEM-resident
                nc = eqn.params.get("num_consts", 0)
                carries = sub.invars[nc:nc + carry_local]
                for b in carries:
                    sub_hbm.discard(id(b))
            c = jaxpr_costs(sub, sub_hbm, _depth + 1)
            flops += mult * c["flops"]
            bytes_ += mult * c["bytes"]
            warnings.extend(c["warnings"])

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
            bytes_ += sum(_aval_bytes(v) for v in eqn.invars
                          if _is_hbm(v, hbm))
            bytes_ += _aval_bytes(eqn.outvars[0])
        elif name == "scan":
            length = eqn.params.get("length", 1)
            recurse(eqn, mult=length,
                    carry_local=eqn.params.get("num_carry", 0))
        elif name == "while":
            warnings.append("while-loop: body counted once")
            recurse(eqn)
        elif name == "cond":
            # max over branches
            best = {"flops": 0.0, "bytes": 0.0, "warnings": []}
            for sub in _sub_jaxprs(eqn.params):
                c = jaxpr_costs(sub, None, _depth + 1)
                if c["flops"] > best["flops"]:
                    best = c
            flops += best["flops"]
            bytes_ += best["bytes"]
            warnings.extend(best["warnings"])
        elif name == "shard_map":
            mesh = eqn.params.get("mesh")
            n = getattr(mesh, "size", 1) or 1
            recurse(eqn, mult=n)   # local shapes x participants
        elif name in ("pjit", "closed_call", "core_call", "remat2",
                      "checkpoint", "custom_vjp_call", "custom_jvp_call",
                      "custom_vjp_call_jaxpr", "jit"):
            recurse(eqn)
        elif name in GATHERS:
            bytes_ += _aval_bytes(eqn.outvars[0])
        elif name in SCATTERS:
            # update operand is the last-but-index input for DUS; just use
            # the smallest non-index operand as the update estimate
            upd = min((_aval_bytes(v) for v in eqn.invars
                       if _aval_bytes(v) > 0), default=0)
            bytes_ += upd
            # in-place update of an HBM buffer: the result is still HBM
            # (decode reads the updated KV cache in the attention matmul)
            if eqn.invars and _is_hbm(eqn.invars[0], hbm):
                hbm.add(id(eqn.outvars[0]))
        elif name in ("sort", "top_k"):
            bytes_ += sum(_aval_bytes(v) for v in eqn.invars)
            flops += sum(_aval_size(v) for v in eqn.invars) * 10
        elif name in ELEMENTWISE_1 or name in ELEMENTWISE_TRANS:
            flops += _aval_size(eqn.outvars[0])
        elif name in REDUCES:
            flops += sum(_aval_size(v) for v in eqn.invars)
        elif name in COLLECTIVES or name in ZERO_COST:
            # view-like ops keep HBM provenance (reshaped weights/caches
            # are still HBM reads for their consumers)
            if name in ("reshape", "transpose", "squeeze", "expand_dims",
                        "slice", "convert_element_type", "copy",
                        "sharding_constraint", "optimization_barrier") \
                    and eqn.invars and eqn.outvars \
                    and _is_hbm(eqn.invars[0], hbm):
                hbm.add(id(eqn.outvars[0]))
        else:
            subs = _sub_jaxprs(eqn.params)
            if subs:
                recurse(eqn)
    return {"flops": flops, "bytes": bytes_, "warnings": warnings}


def fn_costs(fn, *arg_structs) -> Dict[str, Any]:
    """Trace fn with ShapeDtypeStructs and return scan-aware global costs."""
    closed = jax.make_jaxpr(fn)(*arg_structs)
    top_hbm = {id(v) for v in closed.jaxpr.invars} | \
              {id(v) for v in closed.jaxpr.constvars}
    out = jaxpr_costs(closed.jaxpr, top_hbm)
    out["warnings"] = sorted(set(out["warnings"]))
    return out
