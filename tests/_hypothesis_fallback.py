"""Minimal stand-in for ``hypothesis`` so the property tests still run
(with a fixed deterministic sample) when the real library is absent.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, st

Only the strategy surface the suite actually uses is implemented:
``st.integers``, ``st.lists``, ``st.text``. With real hypothesis installed
(the dev extra in pyproject.toml) the fallback is never imported.
"""
from __future__ import annotations

import functools

import numpy as np

DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class st:  # noqa: N801  (mirrors `hypothesis.strategies` module name)
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def text(alphabet="abc", min_size=0, max_size=10):
        chars = list(alphabet)

        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return "".join(chars[int(i)]
                           for i in rng.integers(0, len(chars), n))
        return _Strategy(draw)


def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            # read at call time: @settings may be applied above OR below
            # @given, so the attribute can land on either function object
            n = getattr(runner, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                DEFAULT_EXAMPLES))
            for ex in range(n):
                rng = np.random.default_rng(1234 + ex)
                drawn = [s.example(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # pytest must not see the drawn parameters as fixtures
        del runner.__wrapped__
        return runner

    return deco
