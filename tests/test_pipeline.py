"""Staged serving pipeline: hit-latency decoupling (hit futures resolve
at MIPS-search time, never gated by miss decode), persistent decode-slot
reuse across admissions, background write-back + atomic index swap,
per-request latency stamping, and the MicroBatcher submit-after-stop
window."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core.embedder import HashEmbedder
from repro.core.index import FlatIndex
from repro.core.kb import build_kb
from repro.core.runtime import (BatchedRuntime, BatchedRuntimeCfg,
                                RuntimeCfg, StorInferRuntime)
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.scheduler import MicroBatcher


@pytest.fixture(scope="module")
def engine_parts():
    """Arch config + params + tokenizer; each test builds its own Engine
    (cheap — params are shared, jit caches are per-instance) so decode
    can be slowed per-test without leaking into the shared fixture."""
    kb = build_kb("squad", n_docs=4)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-1.7b")),
        vocab_size=tok.vocab_size, n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    run = M.RunCfg(attn_impl="naive", remat=False)
    return cfg, params, tok, run


def make_engine(parts, decode_delay_s: float = 0.0) -> Engine:
    """A fresh Engine; ``decode_delay_s`` turns it into the slow-decode
    stub — every decode chunk sleeps first, so miss latency is reliably
    dominated by decode while hits stay search-speed."""
    cfg, params, tok, run = parts
    eng = Engine(cfg, params, tok, run, max_len=96, chunk=4)
    if decode_delay_s > 0:
        orig = eng._decode_chunk

        def slowed(*a, **kw):
            time.sleep(decode_delay_s)
            return orig(*a, **kw)

        eng._decode_chunk = slowed
    return eng


@pytest.fixture()
def stored(tmp_path):
    emb = HashEmbedder()
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    qs = ["what is the height of aurora bridge?",
          "who founded the meridian institute?",
          "when was the treaty of helsport signed?"]
    rs = ["the height is two hundred meters.",
          "elena marchetti founded it.",
          "it was signed in 1907."]
    store.add_batch(emb.encode(qs), qs, rs)
    store.flush()
    return emb, store, qs, rs


def _resolve_times(futs, timeout=300):
    """Wait for every future and return its wall-clock resolve stamp."""
    stamps = {}
    lock = threading.Lock()

    def stamp(i):
        def cb(_):
            with lock:
                stamps[i] = time.perf_counter()
        return cb

    for i, f in enumerate(futs):
        f.add_done_callback(stamp(i))
    for f in futs:
        f.result(timeout=timeout)
    return [stamps[i] for i in range(len(futs))]


# ---------------------------------------------------------------------------
# hit-latency decoupling
# ---------------------------------------------------------------------------


def test_hit_futures_resolve_before_any_miss(engine_parts, stored):
    """The tentpole contract: with decode made slow, every hit future —
    even ones submitted AFTER the misses — resolves before any miss
    future, because hits return at MIPS-search time."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts, decode_delay_s=0.05)
    with BatchedRuntime.from_store(
            store, emb, engine=eng,
            cfg=BatchedRuntimeCfg(max_wait_s=0.005, decode_slots=2)) as rt:
        miss_futs = [rt.submit(f"novel zebra question number {i}",
                               max_new=8) for i in range(3)]
        time.sleep(0.15)                  # decode is underway
        hit_futs = [rt.submit(q, max_new=8) for q in qs]
        hit_t = _resolve_times(hit_futs)
        miss_t = _resolve_times(miss_futs)
        hit_res = [f.result() for f in hit_futs]
        miss_res = [f.result() for f in miss_futs]

        assert max(hit_t) < min(miss_t), \
            "a hit future waited on a miss decode"
        assert [r.response for r in hit_res] == rs
        assert all(r.hit and r.source == "store" and r.llm_s == 0.0
                   for r in hit_res)
        assert all((not r.hit) and r.source == "llm" and r.response
                   for r in miss_res)
        # per-submission stamps: each miss carries its own latency, and
        # miss latency dominates hit latency
        assert max(r.latency_s for r in hit_res) \
            < min(r.latency_s for r in miss_res)

        snap = rt.pipeline_stats()
        assert snap["hit"]["n"] == 3 and snap["miss"]["n"] == 3
        assert snap["hit"]["p50_ms"] < snap["miss"]["p50_ms"]
        assert snap["stages"]["search"]["items"] == 6
        assert snap["stages"]["decode"]["items"] == 3
    assert rt.stats.queries == 6
    assert rt.stats.hits == 3 and rt.stats.misses == 3


def test_decode_slots_reused_across_admissions(engine_parts, stored):
    """Misses beyond the slot count refill freed slots on ONE persistent
    scheduler (no per-batch teardown): more admissions than slots, spread
    over multiple waves, through the same BatchScheduler instance."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts)
    with BatchedRuntime.from_store(
            store, emb, engine=eng,
            cfg=BatchedRuntimeCfg(max_wait_s=0.005, decode_slots=2)) as rt:
        pipeline = rt.serve()
        futs = [rt.submit(f"unseen xylophone query variant {i}", max_new=6)
                for i in range(5)]
        res = [f.result(timeout=300) for f in futs]
        assert all(not r.hit and r.response for r in res)
        sched = pipeline.scheduler
        assert sched is rt.serve().scheduler      # one persistent loop
        assert sched.B == 2
        assert sched.admitted == 5                # > slot count
        assert sched.waves >= 2                   # refilled between waves
        assert max(sched.slot_uses) >= 2          # an actual slot reused
        assert sum(sched.slot_uses) == 5


def test_background_rebuild_swaps_index_without_dropping(engine_parts,
                                                         stored):
    """§3.1 write-back + flush_and_rebuild run off the critical path; the
    index swap is atomic — queries in flight during the rebuild resolve
    exactly once with correct responses, and the written-back pair serves
    as a hit afterwards."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts)
    with BatchedRuntime.from_store(
            store, emb, engine=eng,
            cfg=BatchedRuntimeCfg(max_wait_s=0.005, decode_slots=2,
                                  add_misses=True, rebuild_every=1,
                                  async_writeback=True)) as rt:
        novel = "a brand new zebra question never stored before"
        first = rt.submit(novel, max_new=8).result(timeout=300)
        assert not first.hit and first.response
        # hits submitted while the background rebuild races along
        during = [rt.submit(qs[i % 3], max_new=8) for i in range(6)]
        deadline = time.monotonic() + 60
        while rt.stats.index_rebuilds < 1:
            assert time.monotonic() < deadline, "rebuild never happened"
            time.sleep(0.02)
        res = [f.result(timeout=300) for f in during]
        assert [r.response for r in res] == [rs[i % 3] for i in range(6)]
        assert all(r.hit for r in res)
        # the grown store now serves the written-back pair as a hit
        again = rt.submit(novel, max_new=8).result(timeout=300)
        assert again.hit and again.response == first.response
        assert store.count == 4
        assert rt.stats.writebacks == 1


def test_pipeline_without_engine_resolves_misses_empty(stored):
    emb, store, qs, rs = stored
    with BatchedRuntime.from_store(
            store, emb, cfg=BatchedRuntimeCfg(max_wait_s=0.01)) as rt:
        futs = [rt.submit(q) for q in qs + ["novel zebra"]]
        res = [f.result(timeout=60) for f in futs]
        assert [r.hit for r in res] == [True, True, True, False]
        assert res[3].source == "llm" and res[3].response == ""
        snap = rt.pipeline_stats()
        # engine-less misses resolve through the hit-resolve stage
        assert snap["stages"]["resolve"]["items"] == 4
        assert snap["stages"]["decode"]["items"] == 0
        assert set(snap["stages"]) == {"search", "resolve", "decode",
                                       "writeback"}
    assert rt.stats.queries == 4 and rt.stats.hits == 3


def test_pipeline_rejects_bad_knobs(stored):
    emb, store, qs, rs = stored
    with BatchedRuntime.from_store(
            store, emb, cfg=BatchedRuntimeCfg(queue_depth=0)) as rt:
        with pytest.raises(ValueError):
            rt.serve()
    with BatchedRuntime.from_store(
            store, emb, cfg=BatchedRuntimeCfg(decode_slots=0)) as rt:
        with pytest.raises(ValueError):
            rt.serve()


def test_pipeline_submit_after_stop_raises_then_restarts(stored):
    emb, store, qs, rs = stored
    with BatchedRuntime.from_store(store, emb) as rt:
        p = rt.serve()
        assert rt.submit(qs[0]).result(timeout=60).hit
        rt.stop_serving()
        with pytest.raises(RuntimeError, match="not running"):
            p.submit("too late")
        # the runtime stays usable: serve() starts a fresh pipeline
        assert rt.submit(qs[1]).result(timeout=60).hit
        assert rt.serve() is not p


def test_batch_scheduler_temperature_gates_waves(engine_parts):
    """Decode runs one temperature per chunk, so a wave must admit only
    same-temperature requests — a mixed pair forms two waves instead of
    silently decoding with the first slot's temperature."""
    from repro.serving.engine import BatchScheduler, Request
    eng = make_engine(engine_parts)
    sched = BatchScheduler(eng, batch_size=4)
    sched.submit(Request(rid=0, prompt="same length prompt a", max_new=4))
    sched.submit(Request(rid=1, prompt="same length prompt b", max_new=4,
                         temperature=1.0))
    sched._admit()
    assert int(sched.live.sum()) == 1    # greedy wave first, sampled waits
    done = sched.run_to_completion()
    assert len(done) == 2 and sched.waves == 2


def test_submit_temperature_reaches_decode(engine_parts, stored):
    """The facade-level temperature knob flows through submit() to the
    pipelined miss decode (and hits are unaffected by it)."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts)
    with BatchedRuntime.from_store(
            store, emb, engine=eng,
            cfg=BatchedRuntimeCfg(max_wait_s=0.005, decode_slots=2)) as rt:
        miss = rt.submit("novel zebra sampled decode", max_new=6,
                         temperature=1.0).result(timeout=300)
        hit = rt.submit(qs[0], temperature=1.0).result(timeout=300)
        assert not miss.hit and miss.response
        assert hit.hit and hit.response == rs[0]


def test_decode_failure_fails_miss_futures_not_hangs(engine_parts, stored):
    """An engine that dies mid-decode must FAIL the affected miss futures
    (and later arrivals) instead of leaving callers blocked; hits keep
    resolving through the untouched search/resolve stages."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts)

    def boom(*a, **kw):
        raise RuntimeError("decode exploded")

    eng._decode_chunk = boom
    with BatchedRuntime.from_store(
            store, emb, engine=eng,
            cfg=BatchedRuntimeCfg(max_wait_s=0.005, decode_slots=2)) as rt:
        bad = rt.submit("novel zebra breaks the engine", max_new=4)
        with pytest.raises(RuntimeError, match="decode exploded"):
            bad.result(timeout=60)
        later = rt.submit("another novel zebra arrives later", max_new=4)
        with pytest.raises(RuntimeError):
            later.result(timeout=60)
        ok = rt.submit(qs[0]).result(timeout=60)
        assert ok.hit and ok.response == rs[0]


# ---------------------------------------------------------------------------
# synchronous compatibility path: per-request latency stamping
# ---------------------------------------------------------------------------


def test_query_batch_per_request_latency(engine_parts, stored):
    """The satellite fix: results in one batch no longer share a single
    batch-wide latency — a hit is stamped at search-return, a miss when
    its decode slot retired."""
    emb, store, qs, rs = stored
    eng = make_engine(engine_parts, decode_delay_s=0.05)
    rt = BatchedRuntime.from_store(store, emb, engine=eng)
    with rt:
        res = rt.query_batch([qs[0], "unrelated zebra xylophone"],
                             max_new=8)
    hit, miss = res
    assert hit.hit and not miss.hit
    assert hit.latency_s < miss.latency_s
    assert miss.chunks_run >= 1 and miss.llm_s > 0


# ---------------------------------------------------------------------------
# sequential reference path: search embedding threaded to write-back
# ---------------------------------------------------------------------------


class CountingEmbedder(HashEmbedder):
    def __init__(self):
        super().__init__()
        self.calls = 0

    def encode(self, texts):
        self.calls += 1
        return super().encode(texts)


def test_seq_writeback_reuses_search_embedding(engine_parts, tmp_path):
    """StorInferRuntime.query used to re-encode the query for §3.1
    add_misses even though the race's search already embedded it."""
    cfg, params, tok, run = engine_parts
    eng = make_engine(engine_parts)
    emb = CountingEmbedder()
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    store.add_batch(emb.encode(["hello there"]), ["hello there"], ["hi."])
    store.flush()
    rt = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                          engine=eng, cfg=RuntimeCfg(add_misses=True))
    with rt:
        emb.calls = 0
        r = rt.query("completely novel zebra question", max_new=4)
        assert not r.hit and r.response
        assert emb.calls == 1, "write-back re-encoded the query"
        assert store.count == 2


# ---------------------------------------------------------------------------
# MicroBatcher: the submit-after-stop window (satellite)
# ---------------------------------------------------------------------------


def test_microbatcher_rejects_submit_once_stopping():
    """stop() raises the stopping flag BEFORE joining, so a producer can
    no longer enqueue behind the shutdown sentinel (where its future
    would hang forever)."""
    gate = threading.Event()

    def process(subs):
        gate.wait(timeout=10)
        return [s.text for s in subs]

    mb = MicroBatcher(process, max_batch=1, max_wait_s=0.0).start()
    first = mb.submit("in flight")
    time.sleep(0.05)                       # worker picked it up, blocked
    stopper = threading.Thread(target=mb.stop)   # drain; blocks on join
    stopper.start()
    time.sleep(0.1)                        # _stopping is set by now
    with pytest.raises(RuntimeError, match="not running"):
        mb.submit("slipped behind the sentinel")
    gate.set()
    stopper.join(timeout=10)
    assert not stopper.is_alive()
    assert first.result(timeout=10) == "in flight"
