"""End-to-end StorInfer serving: a REAL JAX LM behind the runtime, with
parallel vector search and chunked-decode hit-cancellation (Fig 2), the
continuous-batching scheduler path, and the batched serving runtime
(microbatched admission -> one embed + one MIPS search + one batched
decode, hit slots cancelled mid-flight). The whole system is assembled
declaratively through the ``StorInfer`` facade — one ``SystemCfg`` names
the embedder, the index tier, the runtime thresholds, and the engine arch.

  PYTHONPATH=src python examples/storinfer_serve.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro import EngineCfg, StorInfer, SystemCfg
from repro.core.kb import build_kb, sample_user_queries
from repro.core.runtime import BatchedRuntimeCfg
from repro.core.tokenizer import Tokenizer
from repro.serving.engine import BatchScheduler, Request


def main():
    kb = build_kb("squad", n_docs=10)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=1024)

    # one declarative config: tiny fallback LM (swap real weights via
    # smoke=False), batched admission window, runtime threshold
    cfg = SystemCfg(
        s_th_run=0.9,
        batched=BatchedRuntimeCfg(max_batch=8, max_wait_s=0.02),
        engine=EngineCfg(arch="llama3.2-3b", smoke=True, max_len=128,
                         chunk=4))

    with tempfile.TemporaryDirectory() as td, \
            StorInfer.build(kb, cfg, td, n_pairs=600,
                            tokenizer=tok) as si:
        user = sample_user_queries(kb, 6, seed=3)

        print("=== parallel search + cancellable decode (Fig 2) ===")
        for q, _ in user:
            r = si.query(q, max_new=16)
            print(f"[{r.source:5s} hit={r.hit} chunks={r.chunks_run} "
                  f"lat={r.latency_s:.3f}s] {q!r}")

        print("=== continuous batching with per-slot cancellation ===")
        sched = BatchScheduler(si.engine, batch_size=2)
        for i, (q, _) in enumerate(user[:4]):
            sched.submit(Request(rid=i, prompt=q, max_new=8))
        # a StorInfer hit arrives for request 1 -> cancel mid-flight
        sched.cancel(1)
        done = sched.run_to_completion()
        for r in sorted(done, key=lambda r: r.rid):
            print(f"req {r.rid}: cancelled={r.cancelled} "
                  f"tokens={len(r.out_ids)}")

        print("=== batched StorInfer serving (auto-tiered index) ===")
        with si.serve():
            futs = [si.submit(q, max_new=8) for q, _ in user]
            for (q, _), f in zip(user, futs):
                r = f.result(timeout=120)
                print(f"[{r.source:5s} hit={r.hit} "
                      f"cancelled={r.cancelled}] {q!r}")
        s = si.stats().runtime
        print(f"stats: {s.queries} queries, {s.hits} hits "
              f"({s.hit_rate:.0%}), {s.llm_cancelled} decodes "
              f"hit-cancelled, {s.batches} microbatches")


if __name__ == "__main__":
    main()
