"""Runs the multi-device checks (tests/dist_checks.py) in a subprocess with
8 forced host devices — the main pytest process keeps its single device."""
import pathlib
import subprocess
import sys


def test_distributed_checks():
    script = pathlib.Path(__file__).parent / "dist_checks.py"
    env = {"PYTHONPATH": str(pathlib.Path(__file__).parents[1] / "src"),
           "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ALL DISTRIBUTED CHECKS PASSED" in res.stdout
