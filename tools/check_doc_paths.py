"""Verify that every repo path referenced in the docs exists in the tree.

Scans README.md and docs/*.md for path-like references (backticked or
markdown-linked, anchored at a known top-level directory or a known
top-level file) and fails if any points at nothing — the docs satellite's
guard against module renames silently rotting the architecture docs.

  python tools/check_doc_paths.py          # exit 1 on dangling references
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

# a reference must be anchored at one of these to count as a repo path
DIR_PREFIXES = ("src/", "benchmarks/", "examples/", "tests/", "docs/",
                "tools/", ".github/")
TOP_FILES = {"README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
             "SNIPPETS.md", "CHANGES.md", "pyproject.toml"}

_PATH = re.compile(r"[\w./-]+\.(?:py|md|toml|yml|yaml|json|npy|npz|jsonl)")


def referenced_paths(text: str):
    for m in _PATH.finditer(text):
        # removeprefix, NOT lstrip: lstrip("./") strips the leading dot
        # of ".github/..." and would silently skip those references
        p = m.group(0).removeprefix("./")
        if "*" in p or "XXXX" in p:
            continue                      # glob/placeholder patterns
        if p.startswith(DIR_PREFIXES) or p in TOP_FILES:
            yield p


def check(doc_files=DOC_FILES):
    """Returns a list of (doc, dangling_path) pairs; empty means clean."""
    bad = []
    for doc in doc_files:
        try:
            label = str(doc.relative_to(ROOT))
        except ValueError:
            label = doc.name
        for p in sorted(set(referenced_paths(doc.read_text()))):
            # store/experiment artifacts are generated, not tracked
            if (ROOT / p).exists() or p.startswith("experiments/"):
                continue
            bad.append((label, p))
    return bad


def main() -> int:
    bad = check()
    n_docs = len(DOC_FILES)
    if bad:
        for doc, p in bad:
            print(f"{doc}: dangling reference -> {p}", file=sys.stderr)
        return 1
    print(f"doc path check: {n_docs} docs scanned, all references exist")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
