"""Distributed top-k for the mesh-sharded MIPS index.

The precomputed-query embedding matrix is row-sharded over the "model" axis;
each device scans its shard (one matmul — the Pallas ``mips_topk`` kernel on
real TPUs), takes a local top-k, then an all-gather of the (k-sized)
candidate lists and a final top-k. Traffic per query: shards * k * 8 bytes —
independent of store size N.

Quantized stores shard int8 values + per-row f32 scales (``scales=``): the
local scan scores the int8 shard directly (int8 operand, f32 accumulate —
the MXU's native mixed mode on TPU) and fuses the scale dequant, so each
device holds and streams ~1/4 of the fp32 bytes. int8 cannot encode the
float path's -1e4 padding fill, so padded rows are masked out by global
row id instead (``n_real=``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

NEG = -1e30


def sharded_mips_topk(queries, emb, k, *, mesh, shard_axis="model",
                      local_scan=None, scales=None, n_real=None):
    """queries: (Q, D) replicated; emb: (N, D) row-sharded over shard_axis.

    Returns (scores (Q, k), indices (Q, k)) — replicated, GLOBAL row ids.
    ``local_scan(q, e, k) -> (vals, idx)`` optionally overrides the local
    shard scan (e.g. with the Pallas kernel) on the float path; default is
    matmul + lax.top_k. ``scales`` (row-sharded (N,) f32) switches to the
    int8 shard scan; ``n_real`` masks padded rows (global id >= n_real)
    before the local top-k.
    """

    def default_scan(q, e, k):
        s = q.astype(jnp.float32) @ e.T.astype(jnp.float32)
        return jax.lax.top_k(s, k)

    scan = local_scan or default_scan

    def masked(s, offset):
        if n_real is None:
            return s
        rows = offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        return jnp.where(rows < n_real, s, NEG)

    def combine(v, i, offset):
        i = i + offset
        vg = jax.lax.all_gather(v, shard_axis, axis=1, tiled=True)
        ig = jax.lax.all_gather(i, shard_axis, axis=1, tiled=True)
        vf, pos = jax.lax.top_k(vg, k)
        return vf, jnp.take_along_axis(ig, pos, axis=1)

    if scales is not None:
        def local(q, e, sc):
            offset = jax.lax.axis_index(shard_axis) * e.shape[0]
            s = jax.lax.dot_general(q, e, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            v, i = jax.lax.top_k(masked(s * sc[None, :], offset), k)
            return combine(v, i, offset)

        sm = shard_map(local, mesh=mesh,
                       in_specs=(P(), P(shard_axis), P(shard_axis)),
                       out_specs=(P(), P()), check_vma=False)
        return sm(queries, emb, scales)

    def local(q, e):
        offset = jax.lax.axis_index(shard_axis) * e.shape[0]
        if local_scan is None:
            s = masked(q.astype(jnp.float32) @ e.T.astype(jnp.float32),
                       offset)
            v, i = jax.lax.top_k(s, k)
        else:
            v, i = scan(q, e, k)
        return combine(v, i, offset)

    sm = shard_map(local, mesh=mesh, in_specs=(P(), P(shard_axis)),
                   out_specs=(P(), P()), check_vma=False)
    return sm(queries, emb)
