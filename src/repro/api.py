"""One front door: the ``StorInfer`` system facade.

The paper describes StorInfer as a single system — an offline generator
filling a disk-backed store, a vector index over it, and a runtime racing
that index against LLM inference. This module is that system as ONE
object, so launchers, examples, and benchmarks stop hand-wiring
embedder → generator → store → index → engine → runtime with divergent
defaults:

    from repro import StorInfer, SystemCfg

    kb = build_kb("squad", n_docs=25)
    with StorInfer.build(kb, SystemCfg(), "runs/demo", n_pairs=1500) as si:
        print(si.query("what is the height of aurora bridge?"))

Underneath the facade, the implicit duck-typing is formalized:

* ``EmbedderProtocol`` / ``IndexProtocol`` — checked ``typing.Protocol``s
  every component must satisfy (``encode(texts) -> (n, dim)`` and
  ``search(q, k) -> (scores, ids)`` + ``__len__``).
* String registries — ``EMBEDDERS`` (``"hash"``, ``"minilm"``) and
  ``INDEXES`` (``"auto"``, ``"flat"``, ``"ivf"``, ``"sharded"``,
  ``"none"``) with ``register_embedder`` / ``register_index`` for
  plugging in new components without touching the facade.
* ``index_caps`` — capability flags (``save`` / ``load`` / ``add``) that
  unify FlatIndex / IVFIndex / IncrementalIndex / ShardedIndex behind one
  search contract while exposing what else each tier can do.

``QueryResult`` (per query) and ``RuntimeStats`` (per system) are the
single typed result surface for both the sequential and batched paths;
``SystemStats`` adds the store/index/engine view on top.

Lifecycle:

    StorInfer.build(source, cfg, path, n_pairs=...)   offline: resumable
        wave-batched generation into ``path`` (wraps PrecomputePipeline;
        kill it and rerun — it continues from the manifest checkpoint),
        then opens the serving side over the fresh store.
    StorInfer.open(path, cfg)                         online: store +
        cached auto_index (+ engine when ``cfg.engine`` is set).
    .query() / .query_batch()                         sequential race /
        batched microbatch through one shared index.
    .serve() / .submit()                              stage-decoupled
        serving pipeline (context manager): instant hit returns,
        continuous-batching miss decode, async write-back.
    .stats() / .close()                               accounting, teardown.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from concurrent.futures import Future
from pathlib import Path
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import numpy as np

from repro.core.embedder import HashEmbedder
from repro.core.generator import (GenCfg, QueryLM, SyntheticOracleLM,
                                  chunk_key)
from repro.core.index import (FlatIndex, IVFIndex, IncrementalIndex,
                              ShardedIndex, auto_index, cached_device_store,
                              device_store_for)
from repro.core.precompute import (PrecomputeCfg, PrecomputePipeline,
                                   PrecomputeStats)
from repro.core.runtime import (BatchedRuntime, BatchedRuntimeCfg,
                                QueryResult, RuntimeCfg, RuntimeStats,
                                StorInferRuntime)
from repro.core.store import SHARD_ROWS, PrecomputedStore
from repro.core.tokenizer import Tokenizer

__all__ = [
    "EmbedderProtocol", "IndexProtocol", "IndexCaps", "index_caps",
    "register_embedder", "register_index", "make_embedder", "make_index",
    "make_pipeline", "tier_of", "EngineCfg", "SystemCfg", "SystemStats",
    "StorInfer", "QueryResult", "RuntimeStats",
]


# ---------------------------------------------------------------------------
# Component protocols (the formerly-implicit duck types, now checked)
# ---------------------------------------------------------------------------


@runtime_checkable
class EmbedderProtocol(Protocol):
    """Anything that maps texts to L2-normalized ``(n, dim)`` float32."""

    dim: int

    def encode(self, texts: Sequence[str]) -> np.ndarray: ...


@runtime_checkable
class IndexProtocol(Protocol):
    """One search contract for every tier: ``search(q, k)`` over an
    ``(n, dim)`` query batch returns ``(scores, ids)`` each ``(n, k)``."""

    def search(self, queries: np.ndarray,
               k: int) -> Tuple[np.ndarray, np.ndarray]: ...

    def __len__(self) -> int: ...


@dataclasses.dataclass(frozen=True)
class IndexCaps:
    """What an index can do beyond ``search``: persist its build product
    (``save``/``load``, IVF's k-means fit) and grow in place (``add``,
    the incremental dedup tier)."""
    save: bool
    load: bool
    add: bool


def index_caps(index) -> IndexCaps:
    return IndexCaps(save=callable(getattr(index, "save", None)),
                     load=callable(getattr(type(index), "load", None)),
                     add=callable(getattr(index, "add", None)))


_TIER_NAMES = {FlatIndex: "flat", IVFIndex: "ivf", ShardedIndex: "sharded",
               IncrementalIndex: "incremental"}


def tier_of(index) -> str:
    """Registry-name of an index instance (``"none"`` for store-only)."""
    if index is None:
        return "none"
    return _TIER_NAMES.get(type(index), type(index).__name__.lower())


# ---------------------------------------------------------------------------
# String registries
# ---------------------------------------------------------------------------

EMBEDDERS: Dict[str, Callable[..., Any]] = {}
INDEXES: Dict[str, Callable[..., Any]] = {}


def register_embedder(name: str, factory: Callable[..., Any]):
    """Register ``factory(tokenizer=None, **kw) -> EmbedderProtocol``."""
    EMBEDDERS[name] = factory
    return factory


def register_index(name: str, factory: Callable[..., Any]):
    """Register ``factory(source, mesh=None, cache_dir=None, **kw) ->
    IndexProtocol`` where ``source`` is a store, an embeddings view, or a
    raw ``(n, dim)`` array."""
    INDEXES[name] = factory
    return factory


def make_embedder(spec: Union[str, EmbedderProtocol], *, tokenizer=None,
                  **kw) -> EmbedderProtocol:
    """Resolve a registry name (or validate an instance) to an embedder."""
    if isinstance(spec, str):
        try:
            factory = EMBEDDERS[spec]
        except KeyError:
            raise KeyError(f"unknown embedder {spec!r}; registered: "
                           f"{sorted(EMBEDDERS)}") from None
        emb = factory(tokenizer=tokenizer, **kw)
    else:
        emb = spec
    if not isinstance(emb, EmbedderProtocol):
        raise TypeError(f"{type(emb).__name__} does not satisfy "
                        "EmbedderProtocol (needs .dim and .encode)")
    return emb


def _embs_of(source):
    return source.embeddings() if hasattr(source, "embeddings") else source


def make_index(spec: Union[str, IndexProtocol], source=None, *, mesh=None,
               cache_dir=None, **kw) -> Optional[IndexProtocol]:
    """Resolve a tier name (or validate an instance) to an index over
    ``source``. ``"none"`` returns None (store-only mode)."""
    if isinstance(spec, str):
        if spec == "none":
            return None
        try:
            factory = INDEXES[spec]
        except KeyError:
            raise KeyError(f"unknown index tier {spec!r}; registered: "
                           f"{sorted(INDEXES)}") from None
        idx = factory(source, mesh=mesh, cache_dir=cache_dir, **kw)
    else:
        idx = spec
    if not isinstance(idx, IndexProtocol):
        raise TypeError(f"{type(idx).__name__} does not satisfy "
                        "IndexProtocol (needs .search and __len__)")
    return idx


def _minilm_factory(tokenizer=None, **kw):
    if tokenizer is None:
        raise ValueError("the 'minilm' embedder needs tokenizer=")
    from repro.core.embedder import MiniLMEncoder
    return MiniLMEncoder(tokenizer, **kw)


def _sharded_factory(source, mesh=None, cache_dir=None, **kw):
    if mesh is None:
        raise ValueError("the 'sharded' index tier needs mesh=")
    return ShardedIndex(np.asarray(_embs_of(source), np.float32), mesh, **kw)


def _flat_factory(source, mesh=None, cache_dir=None, use_kernel=False,
                  **kw):
    # stores get the per-store DeviceStore cache, so §3.1 write-back
    # rebuilds of a pinned "flat" tier append deltas instead of
    # re-uploading the matrix (auto_index does the same for "auto")
    if hasattr(source, "embeddings"):
        dev = device_store_for(
            source, layout="kernel" if use_kernel else "auto")
        return FlatIndex(device=dev, use_kernel=use_kernel, **kw)
    return FlatIndex(_embs_of(source), use_kernel=use_kernel, **kw)


register_embedder("hash", lambda tokenizer=None, **kw: HashEmbedder(**kw))
register_embedder("minilm", _minilm_factory)
register_index("auto", lambda source, mesh=None, cache_dir=None, **kw:
               auto_index(source, mesh, cache_dir=cache_dir, **kw))
register_index("flat", _flat_factory)
register_index("ivf", lambda source, mesh=None, cache_dir=None, **kw:
               IVFIndex(_embs_of(source),
                        device=(cached_device_store(source)
                                if hasattr(source, "embeddings") else None),
                        **kw))
register_index("sharded", _sharded_factory)


# ---------------------------------------------------------------------------
# Declarative system configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineCfg:
    """The on-device fallback LM behind the runtime race. ``smoke=True``
    shrinks the arch (``configs.reduced`` + ``smoke_layers`` layers, vocab
    from the tokenizer) so the full system runs on a laptop CPU; real
    deployments set ``smoke=False`` and swap trained params in."""
    arch: str = "qwen3-1.7b"
    smoke: bool = True
    smoke_layers: int = 2
    max_len: int = 160
    chunk: int = 8
    seed: int = 0


@dataclasses.dataclass
class SystemCfg:
    """Everything needed to assemble a StorInfer system, declaratively.

    ``embedder``/``index`` are registry names (or ready instances
    satisfying the protocols); ``engine=None`` runs search-only (misses
    return empty responses); ``s_th_run`` is a convenience that overrides
    the runtime threshold on BOTH the sequential and batched paths.
    """
    embedder: Union[str, EmbedderProtocol] = "hash"
    embedder_kw: dict = dataclasses.field(default_factory=dict)
    index: Union[str, IndexProtocol] = "auto"
    index_kw: dict = dataclasses.field(default_factory=dict)
    cache_index: bool = True           # persist/load the IVF fit in the
    #                                    store root (auto tier only)
    gen: GenCfg = dataclasses.field(default_factory=GenCfg)
    precompute: PrecomputeCfg = dataclasses.field(
        default_factory=PrecomputeCfg)
    runtime: RuntimeCfg = dataclasses.field(default_factory=RuntimeCfg)
    batched: BatchedRuntimeCfg = dataclasses.field(
        default_factory=BatchedRuntimeCfg)
    engine: Optional[EngineCfg] = None
    s_th_run: Optional[float] = None
    # -- staged-pipeline conveniences (override cfg.batched's knobs) ------
    decode_slots: Optional[int] = None     # persistent decode slot count
    queue_depth: Optional[int] = None      # per-stage bounded queue depth
    async_writeback: Optional[bool] = None  # §3.1 write-back off the
    #                                         critical path (background
    #                                         rebuild + atomic index swap)
    emb_dtype: str = "float16"         # store embedding dtype
    quantize: bool = False             # convenience: emb_dtype="int8"
    #                                    (symmetric per-row int8 shards +
    #                                    scales; the device-resident int8
    #                                    MIPS path serves them)
    shard_rows: int = SHARD_ROWS       # store shard size (rows)

    def __post_init__(self):
        if self.s_th_run is not None:
            self.runtime = dataclasses.replace(self.runtime,
                                               s_th_run=self.s_th_run)
            self.batched = dataclasses.replace(self.batched,
                                               s_th_run=self.s_th_run)
        pipeline_kw = {k: getattr(self, k)
                       for k in ("decode_slots", "queue_depth",
                                 "async_writeback")
                       if getattr(self, k) is not None}
        if pipeline_kw:
            self.batched = dataclasses.replace(self.batched, **pipeline_kw)
        if self.quantize:
            self.emb_dtype = "int8"
        elif self.emb_dtype == "int8":
            self.quantize = True


@dataclasses.dataclass
class SystemStats:
    """One accounting view over the whole system: merged runtime counters
    (sequential + batched paths), the store's storage split, which index
    tier is serving, and — when the staged serving pipeline has run — its
    per-stage queue depth / wait accounting plus hit/miss latency
    percentiles (``pipeline["stages"]``, ``pipeline["hit"]``,
    ``pipeline["miss"]``; see ``serving.scheduler.PipelineStats``)."""
    runtime: RuntimeStats
    store_rows: int
    store_bytes: dict
    index_tier: str
    index_rows: int
    has_engine: bool
    pipeline: Optional[dict] = None


# ---------------------------------------------------------------------------
# Assembly helpers
# ---------------------------------------------------------------------------


def make_pipeline(cfg: SystemCfg, lm: QueryLM,
                  tokenizer) -> PrecomputePipeline:
    """The offline half on its own (store-free benchmarking, custom
    drivers); ``StorInfer.build`` uses this internally."""
    emb = make_embedder(cfg.embedder, tokenizer=tokenizer,
                        **cfg.embedder_kw)
    return PrecomputePipeline(lm, emb, tokenizer, cfg.gen, cfg.precompute)


def _resolve_source(source, lm, tokenizer):
    """``source`` is a KB (chunks + oracle LM + tokenizer derived) or a
    raw chunk sequence (``lm=`` required)."""
    if hasattr(source, "docs"):
        texts = [d.text() for d in source.docs]
        chunks = [chunk_key(d.doc_id, d.text()) for d in source.docs]
        lm = lm if lm is not None else SyntheticOracleLM(source)
        tokenizer = tokenizer or Tokenizer.from_texts(texts)
    else:
        chunks = list(source)
        if lm is None:
            raise ValueError("building from raw chunks needs lm= "
                             "(a QueryLM); a KB source derives its own")
        tokenizer = tokenizer or Tokenizer.from_texts(chunks)
    return chunks, lm, tokenizer


def _tokenizer_from_store(store, sample: int = 512):
    """Vocab for an engine opened over a bare store: built from a sample
    of the stored pairs (the store IS the corpus at serve time)."""
    texts = []
    for row in range(min(store.count, sample)):
        q, r = store.get_pair(row)
        texts += [q, r]
    return Tokenizer.from_texts(texts or ["empty"])


def _build_engine(ecfg: EngineCfg, tokenizer):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import model as M
    from repro.serving.engine import Engine
    cfg = get_config(ecfg.arch)
    if ecfg.smoke:
        cfg = dataclasses.replace(reduced(cfg),
                                  vocab_size=tokenizer.vocab_size,
                                  n_layers=ecfg.smoke_layers)
    params = M.init_model(jax.random.PRNGKey(ecfg.seed), cfg,
                          dtype=jnp.float32)
    return Engine(cfg, params, tokenizer,
                  M.RunCfg(attn_impl="naive", remat=False),
                  max_len=ecfg.max_len, chunk=ecfg.chunk)


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


class StorInfer:
    """The StorInfer system behind one handle: store + index + embedder
    (+ optional engine), with the sequential reference runtime and the
    batched serving runtime sharing that one index.

    Construct via ``StorInfer.build`` (offline: generate into a store,
    then serve it) or ``StorInfer.open`` (online: serve an existing
    store). Direct construction from ready components is supported and
    protocol-checked.
    """

    def __init__(self, store: PrecomputedStore, embedder, index=None, *,
                 engine=None, cfg: SystemCfg = None, mesh=None,
                 build_stats: Optional[PrecomputeStats] = None):
        self.store = store
        self.embedder = make_embedder(embedder)   # validates the protocol
        self.index = make_index(index) if index is not None else None
        self.engine = engine
        self.cfg = cfg or SystemCfg()
        self.mesh = mesh
        self.build_stats = build_stats
        self.index_seconds = 0.0    # wall-clock of the index build/load
        self._seq_stats = RuntimeStats()
        self._seq = self._batched = None
        if self.index is not None:
            self._seq = StorInferRuntime(self.index, store, self.embedder,
                                         engine, cfg=self.cfg.runtime)
            cache_dir = str(store.root) if self.cfg.cache_index else None
            # §3.1 write-back rebuilds must honor the DECLARED tier and
            # its kwargs (cfg.index_kw is factory-specific — auto_index
            # would reject e.g. an "ivf" tier's n_lists); an instance-
            # configured index has no recipe, so rebuilds fall back to
            # auto_index with just the cache
            rebuild = None
            if isinstance(self.cfg.index, str):
                rebuild = lambda store, mesh: make_index(   # noqa: E731
                    self.cfg.index, store, mesh=mesh, cache_dir=cache_dir,
                    **self.cfg.index_kw)
            auto_kw = {"cache_dir": cache_dir} if cache_dir else {}
            self._batched = BatchedRuntime(self.index, store,
                                           self.embedder, engine,
                                           cfg=self.cfg.batched, mesh=mesh,
                                           auto_index_kw=auto_kw,
                                           rebuild=rebuild)

    # -- lifecycle ------------------------------------------------------------
    @classmethod
    def build(cls, source, cfg: SystemCfg = None, path=None, *,
              n_pairs: int, lm: QueryLM = None, tokenizer=None,
              seed: int = 0, resume: bool = True, on_wave=None, mesh=None,
              _kill_after_waves: Optional[int] = None) -> "StorInfer":
        """Offline build (resumable), then open the serving side.

        ``source`` is a KB or a sequence of knowledge-chunk strings.
        If ``path`` holds a checkpointed build, generation CONTINUES from
        it (``resume=False`` refuses); kill + rerun yields a store
        byte-identical to an uninterrupted run (see core/precompute.py).
        A crash mid-build releases the store handle without committing
        anything past the last checkpoint.
        """
        cfg = cfg or SystemCfg()
        if path is None:
            raise ValueError("build needs a store path")
        chunks, lm, tokenizer = _resolve_source(source, lm, tokenizer)
        pipe = make_pipeline(cfg, lm, tokenizer)
        try:
            store = PrecomputedStore.open_(path)
        except FileNotFoundError:
            store = PrecomputedStore(path, dim=pipe.embedder.dim,
                                     emb_dtype=cfg.emb_dtype,
                                     shard_rows=cfg.shard_rows)
        try:
            _, _, _, stats = pipe.run(
                chunks, n_pairs, store=store, seed=seed, resume=resume,
                on_wave=on_wave, _kill_after_waves=_kill_after_waves)
        except BaseException:
            store.abort()      # crash semantics: keep the last checkpoint
            raise
        return cls._from_store(store, cfg, tokenizer=tokenizer, mesh=mesh,
                               embedder=pipe.embedder, build_stats=stats)

    @classmethod
    def open(cls, path, cfg: SystemCfg = None, *, tokenizer=None,
             mesh=None) -> "StorInfer":
        """Open an existing store for serving: memory-mapped shards, the
        cached ``auto_index`` tier (a persisted IVF fit loads instead of
        refitting), and the engine when ``cfg.engine`` is set."""
        store = PrecomputedStore.open_(path)
        return cls._from_store(store, cfg, tokenizer=tokenizer, mesh=mesh)

    @classmethod
    def _from_store(cls, store, cfg=None, *, tokenizer=None, mesh=None,
                    embedder=None, build_stats=None) -> "StorInfer":
        cfg = cfg or SystemCfg()
        if embedder is None:
            embedder = make_embedder(cfg.embedder, tokenizer=tokenizer,
                                     **cfg.embedder_kw)
        cache_dir = str(store.root) if cfg.cache_index else None
        t0 = time.perf_counter()
        index = make_index(cfg.index, store, mesh=mesh,
                           cache_dir=cache_dir, **cfg.index_kw)
        index_s = time.perf_counter() - t0
        engine = None
        if cfg.engine is not None:
            tok = tokenizer or _tokenizer_from_store(store)
            engine = _build_engine(cfg.engine, tok)
        si = cls(store, embedder, index, engine=engine, cfg=cfg,
                 mesh=mesh, build_stats=build_stats)
        si.index_seconds = index_s
        return si

    def close(self):
        """Stop serving, release runtimes, flush + close the store."""
        if self._batched is not None:
            self._batched.close()
        if self._seq is not None:
            self._seq.close()
        self.store.close()

    def __enter__(self) -> "StorInfer":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- query paths ----------------------------------------------------------
    def _require_index(self, what: str):
        if self.index is None:
            raise RuntimeError(
                f"{what} needs an index; this system was opened with "
                "index='none' (store-only mode)")

    def query(self, text: str, *, max_new: int = 32,
              temperature=None) -> QueryResult:
        """The paper's one-query race (sequential reference path)."""
        self._require_index("query()")
        r = self._seq.query(text, max_new=max_new, temperature=temperature)
        s = self._seq_stats
        s.queries += 1
        s.hits += int(r.hit)
        s.misses += int(not r.hit)
        s.llm_cancelled += int(r.cancelled)
        # batches stays batched-path-only: a sequential query is not a
        # microbatch, and items/batches must keep meaning amortization
        return r

    def query_batch(self, texts: Sequence[str], *,
                    max_new: Union[int, Sequence[int]] = 32,
                    temperature=None) -> List[QueryResult]:
        """One embed + one MIPS dispatch + one batched decode, hit slots
        cancelled mid-flight (the serving path)."""
        self._require_index("query_batch()")
        return self._batched.query_batch(texts, max_new=max_new,
                                         temperature=temperature)

    @contextlib.contextmanager
    def serve(self):
        """Staged-pipeline admission: inside the ``with`` block,
        ``submit()`` enqueues queries into the stage-decoupled serving
        loop — hits resolve the moment their microbatch's MIPS search
        returns, misses decode on the persistent continuous-batching
        scheduler, write-backs rebuild in the background; on exit the
        pipeline drains and stops (the system stays usable)."""
        self._require_index("serve()")
        self._batched.serve()
        try:
            yield self
        finally:
            self._batched.stop_serving()

    def submit(self, text: str, *, max_new: int = 32,
               temperature=None) -> Future:
        """Enqueue one query (starts the serving pipeline on first use);
        a hit resolves at search time, a miss at decode completion with
        ``temperature`` applied to its decode."""
        self._require_index("submit()")
        return self._batched.submit(text, max_new=max_new,
                                    temperature=temperature)

    # -- accounting -----------------------------------------------------------
    def stats(self) -> SystemStats:
        merged = RuntimeStats(**dataclasses.asdict(self._seq_stats))
        if self._batched is not None:
            b = self._batched.stats
            for f in dataclasses.fields(RuntimeStats):
                setattr(merged, f.name,
                        getattr(merged, f.name) + getattr(b, f.name))
        return SystemStats(
            runtime=merged, store_rows=self.store.count,
            store_bytes=self.store.storage_bytes(),
            index_tier=tier_of(self.index),
            index_rows=len(self.index) if self.index is not None else 0,
            has_engine=self.engine is not None,
            pipeline=(self._batched.pipeline_stats()
                      if self._batched is not None else None))
