"""The paper's own serving model: LLaMA-3.1-8B-class dense LM.

StorInfer generates and serves with LLaMA-3.1-8B (fallback: LLaMA-3.2-1B on
device). This config is the 8B backbone used by the paper-reproduction
benchmarks; `storinfer-paper-1b` is the on-device fallback.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="storinfer-paper-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    norm_eps=1e-5,
))

FALLBACK_1B = register(ModelConfig(
    name="storinfer-paper-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=64,
    rope_theta=5e5,
    tie_embeddings=True,
    norm_eps=1e-5,
))
