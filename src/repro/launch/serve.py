"""Serving launcher: StorInfer runtime in front of any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --n-pairs 800 --n-queries 40

Builds (or loads) a precomputed store from a KB, stands up the fallback
engine for the chosen arch, and serves a query stream through the parallel
search + cancellable-decode runtime, reporting hit rate and effective
latency. On real hardware drop --smoke to load the full config.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.embedder import HashEmbedder
from repro.core.generator import (GenCfg, QueryGenerator, SyntheticOracleLM,
                                  chunk_key)
from repro.core.index import FlatIndex, IVFIndex, auto_index
from repro.core.kb import build_kb, sample_user_queries
from repro.core.runtime import RuntimeCfg, StorInferRuntime
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--dataset", default="squad")
    ap.add_argument("--n-pairs", type=int, default=800)
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--s-th-run", type=float, default=0.9)
    ap.add_argument("--index", choices=("auto", "flat", "ivf"),
                    default="auto",
                    help="auto picks the tier from store size and loads a "
                         "persisted IVF fit from the store root if present")
    ap.add_argument("--store", default=None,
                    help="store dir (default: temp, rebuilt)")
    args = ap.parse_args()

    kb = build_kb(args.dataset, n_docs=20)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=2048)
    emb = HashEmbedder()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(reduced(cfg), vocab_size=tok.vocab_size,
                                  n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    engine = Engine(cfg, params, tok,
                    M.RunCfg(attn_impl="naive", remat=False),
                    max_len=160, chunk=8)

    import tempfile
    store_dir = args.store or tempfile.mkdtemp(prefix="storinfer_")
    try:
        store = PrecomputedStore.open_(store_dir)
        print(f"loaded store: {store.count} pairs")
    except FileNotFoundError:
        store = PrecomputedStore(store_dir, dim=emb.dim)
        gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok,
                             GenCfg(dedup=True))
        chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
        _, _, _, st = gen.generate(chunks, args.n_pairs, store=store)
        store.flush()
        print(f"built store: {store.count} pairs "
              f"({st.discarded} discarded), "
              f"{store.storage_bytes()['total_bytes'] / 1e6:.2f} MB")

    if args.index == "auto":
        index = auto_index(store, cache_dir=store.root)
    else:
        embs = store.embeddings()
        index = FlatIndex(embs) if args.index == "flat" else IVFIndex(embs)
    rt = StorInferRuntime(index, store, emb, engine=engine,
                          cfg=RuntimeCfg(s_th_run=args.s_th_run))

    user = sample_user_queries(kb, args.n_queries, seed=9)
    hits, lat = 0, []
    for q, _ in user:
        r = rt.query(q, max_new=16)
        hits += r.hit
        lat.append(r.latency_s)
    print(f"hit_rate={hits / len(user):.3f} "
          f"mean_latency={np.mean(lat):.3f}s p50={np.median(lat):.3f}s")


if __name__ == "__main__":
    main()
