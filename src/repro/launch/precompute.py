"""Offline precompute launcher: paper-scale store builds (§3.2/§3.3).

  PYTHONPATH=src python -m repro.launch.precompute \
      --dataset squad --n-pairs 150000 --wave 32 --store runs/squad150k

Builds (or resumes — the default when the store directory already holds a
checkpointed build) a deduplicated precomputed-query store via
``StorInfer.build`` (the batched ``PrecomputePipeline`` underneath), then
fits and persists the serving index into the store root so
``StorInfer.open`` / ``BatchedRuntime.from_store(..., cache_dir="store")``
reopen it without re-running k-means. Kill it any time: rerunning the same
command continues from the last checkpoint and produces a store
byte-identical to an uninterrupted run.
"""
import argparse
import json
import time
from pathlib import Path

from repro.api import StorInfer, SystemCfg, tier_of
from repro.core.kb import build_kb
from repro.core.precompute import STATE_KEY, PrecomputeCfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="squad",
                    choices=("squad", "narrativeqa", "triviaqa"))
    ap.add_argument("--n-docs", type=int, default=None,
                    help="KB size (default: dataset profile)")
    ap.add_argument("--n-pairs", type=int, default=150_000,
                    help="target deduplicated pairs (paper: 150K)")
    ap.add_argument("--wave", type=int, default=32,
                    help="candidates per batched step")
    ap.add_argument("--store", required=True, help="store directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-every", type=int, default=64,
                    help="waves between resume checkpoints")
    # audited for the serve.py store_true/default=True trap: these three
    # default to False, so plain store_true keeps both states reachable
    ap.add_argument("--background-recluster", action="store_true",
                    help="refit the dedup IVF in a thread (faster, gives "
                         "up kill/resume determinism)")
    ap.add_argument("--embedder", choices=("hash", "minilm"),
                    default="hash")
    ap.add_argument("--emb-dtype",
                    choices=("float16", "float32", "int8"),
                    default="float16",
                    help="store embedding dtype; int8 writes symmetric "
                         "per-row quantized shards + f32 scales (~26%% of "
                         "fp32 bytes) served by the device-resident int8 "
                         "MIPS path")
    ap.add_argument("--fresh", action="store_true",
                    help="refuse to resume; store dir must be empty")
    ap.add_argument("--no-index", action="store_true",
                    help="skip fitting + persisting the serving index")
    args = ap.parse_args(argv)

    kb = build_kb(args.dataset, seed=args.seed, n_docs=args.n_docs)
    cfg = SystemCfg(
        embedder=args.embedder,
        index="none" if args.no_index else "auto",
        emb_dtype=args.emb_dtype,
        precompute=PrecomputeCfg(
            wave=args.wave, checkpoint_every=args.checkpoint_every,
            background_recluster=args.background_recluster))

    manifest = Path(args.store) / "manifest.json"
    if manifest.exists():
        man = json.loads(manifest.read_text())
        done = man.get("extra", {}).get(STATE_KEY, {}).get("generated", "?")
        print(f"resuming store {args.store}: {man.get('count', '?')} rows "
              f"(checkpoint says {done})")
    else:
        print(f"fresh store {args.store}")

    t0 = time.perf_counter()
    last = [t0]

    def on_wave(waves, generated, discarded, mode):
        if time.perf_counter() - last[0] >= 5.0:
            last[0] = time.perf_counter()
            rate = generated / (time.perf_counter() - t0 + 1e-9)
            print(f"  wave {waves}: {generated}/{args.n_pairs} pairs "
                  f"({discarded} discarded, dedup={mode}, "
                  f"{rate:.0f} pairs/s this run)")

    si = StorInfer.build(kb, cfg, args.store, n_pairs=args.n_pairs,
                         seed=args.seed, resume=not args.fresh,
                         on_wave=on_wave)
    with si:
        stats = si.build_stats
        sb = si.store.storage_bytes()
        print(f"build done: {si.store.count} rows "
              f"({stats.generated} this run, {stats.discarded} discarded, "
              f"{stats.pairs_per_sec:.0f} pairs/s, "
              f"dedup index ended {stats.index_mode}); "
              f"store {sb['total_bytes'] / 1e6:.1f} MB "
              f"({sb['index_bytes'] / 1e6:.1f} embeddings + "
              f"{sb['metadata_bytes'] / 1e6:.1f} metadata)")

        if si.index is not None:
            tier = tier_of(si.index)
            how = "loaded" if getattr(si.index, "loaded_from", None) \
                else "built"
            dt = si.index_seconds
            print(f"serving index: {tier} {how} in {dt:.1f}s "
                  f"(cache: {si.store.root}/index_ivf.npz)"
                  if tier == "ivf" else
                  f"serving index: {tier} ({dt:.1f}s; nothing to cache "
                  "below the IVF boundary)")


if __name__ == "__main__":
    main()
