"""Query embedders.

Two implementations behind one ``encode(texts) -> (n, dim) L2-normalized``
interface:

* ``HashEmbedder`` — signed n-gram feature hashing (the "hashing trick").
  Deterministic, no training, lexically semantic: paraphrases sharing
  content words land close in cosine space. This is the default for the
  paper-reproduction benchmarks (plays the role of all-MiniLM-L6-v2, whose
  weights don't ship in this container).

* ``MiniLMEncoder`` — an all-MiniLM-class (6L, 384d) JAX transformer
  encoder with mean pooling, plus an InfoNCE contrastive trainer over
  synthetic paraphrase pairs — the full neural path, used by tests/examples
  to prove the system runs a real JAX encoder end-to-end.

MIPS on L2-normalized embeddings == cosine similarity (the paper's metric).
"""
from __future__ import annotations

import dataclasses
import re
import zlib
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as Lyr
from repro.models import model as M

_WORDS = re.compile(r"\w+")


class HashEmbedder:
    def __init__(self, dim: int = 384, ngrams=(1, 2), seed: int = 0):
        self.dim = dim
        self.ngrams = ngrams
        self.seed = seed

    def _features(self, text: str):
        ws = _WORDS.findall(text.lower())
        feats = []
        for n in self.ngrams:
            for i in range(len(ws) - n + 1):
                feats.append(" ".join(ws[i:i + n]))
        return feats

    def encode(self, texts: List[str]) -> np.ndarray:
        out = np.zeros((len(texts), self.dim), np.float32)
        for i, t in enumerate(texts):
            for f in self._features(t):
                h = zlib.crc32((f + f"#{self.seed}").encode())
                idx = h % self.dim
                sign = 1.0 if (h >> 17) & 1 else -1.0
                out[i, idx] += sign
        norms = np.linalg.norm(out, axis=1, keepdims=True)
        return out / np.maximum(norms, 1e-9)


# ---------------------------------------------------------------------------
# MiniLM-class JAX encoder
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EncoderCfg:
    vocab_size: int
    dim: int = 384
    n_layers: int = 6
    n_heads: int = 6
    d_ff: int = 1536
    max_len: int = 64


def _enc_model_cfg(cfg: EncoderCfg):
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="minilm-enc", family="dense", n_layers=cfg.n_layers,
        d_model=cfg.dim, n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
        d_ff=cfg.d_ff, vocab_size=cfg.vocab_size,
        head_dim=cfg.dim // cfg.n_heads, gated_mlp=False, mlp_act="gelu",
        rope_kind="none", dtype="float32")


class MiniLMEncoder:
    """Mean-pooled transformer encoder; ``encode`` batches + L2-normalizes."""

    def __init__(self, tokenizer, cfg: EncoderCfg = None, seed: int = 0,
                 max_batch: int = 256):
        self.tok = tokenizer
        self.cfg = cfg or EncoderCfg(vocab_size=tokenizer.vocab_size)
        self.dim = self.cfg.dim
        self.max_batch = max_batch
        self.mcfg = _enc_model_cfg(self.cfg)
        key = jax.random.PRNGKey(seed)
        self.params = self._init(key)
        self._fwd = jax.jit(self._forward)

    def _init(self, key):
        ks = jax.random.split(key, 3)
        return {
            "embed": {"w": (jax.random.normal(
                ks[0], (self.cfg.vocab_size, self.cfg.dim), jnp.float32)
                * self.cfg.dim ** -0.5)},
            "blocks": M._stack_init(ks[1], self.mcfg, "enc",
                                    self.cfg.n_layers, jnp.float32),
            "final_norm": Lyr.rmsnorm_init(self.cfg.dim, jnp.float32),
        }

    def _forward(self, params, tokens, mask):
        """tokens (B, L) int32; mask (B, L) f32. Returns (B, dim) L2-normed."""
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        x = x + Lyr.sinusoidal_positions(tokens.shape[1],
                                         self.cfg.dim)[None]
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32),
                               tokens.shape)
        run = M.RunCfg(attn_impl="naive", remat=False, scan_layers=True)
        x, _, _ = M._scan_stack(self.mcfg, run, params["blocks"], x, pos,
                                kind="enc", build_cache=False)
        x = Lyr.rmsnorm(params["final_norm"], x, 1e-6)
        pooled = (x * mask[..., None]).sum(1) / jnp.maximum(
            mask.sum(1, keepdims=True), 1.0)
        return pooled / jnp.maximum(
            jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)

    def _batch(self, texts, pad_to: int = 0):
        L = self.cfg.max_len
        rows = max(len(texts), pad_to)
        toks = np.zeros((rows, L), np.int32)
        mask = np.zeros((rows, L), np.float32)
        for i, t in enumerate(texts):
            ids = self.tok.encode(t)[:L]
            toks[i, :len(ids)] = ids
            mask[i, :len(ids)] = 1.0
        return jnp.asarray(toks), jnp.asarray(mask)

    @staticmethod
    def _bucket(n: int) -> int:
        """Next power of two: one jit compilation per bucket instead of one
        per distinct batch size (precompute waves and serving microbatches
        arrive in many sizes)."""
        b = 1
        while b < n:
            b *= 2
        return b

    def encode(self, texts: List[str]) -> np.ndarray:
        """Batched + L2-normalized. Batches are padded to power-of-two
        buckets (padding rows carry an all-zero mask and are sliced off)
        and chunked at ``max_batch`` so arbitrarily large precompute waves
        neither recompile nor blow device memory."""
        if not texts:
            return np.zeros((0, self.dim), np.float32)
        out = []
        for lo in range(0, len(texts), self.max_batch):
            chunk = texts[lo:lo + self.max_batch]
            toks, mask = self._batch(chunk, pad_to=self._bucket(len(chunk)))
            out.append(np.asarray(
                self._fwd(self.params, toks, mask))[:len(chunk)])
        return out[0] if len(out) == 1 else np.concatenate(out, axis=0)

    # -- contrastive training (InfoNCE over paraphrase pairs) --------------
    def train_contrastive(self, pairs, *, steps=200, bs=32, lr=1e-3,
                          temp=0.07, seed=0):
        """pairs: list of (text_a, text_b) positives. In-batch negatives."""
        rng = np.random.default_rng(seed)

        def loss_fn(params, ta, ma, tb, mb):
            za = self._forward(params, ta, ma)
            zb = self._forward(params, tb, mb)
            logits = za @ zb.T / temp
            labels = jnp.arange(za.shape[0])
            ll = jax.nn.log_softmax(logits, axis=-1)
            lt = jax.nn.log_softmax(logits.T, axis=-1)
            return -(ll[labels, labels].mean() + lt[labels, labels].mean())

        @jax.jit
        def step(params, opt, ta, ma, tb, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, ta, ma, tb, mb)
            new_p, new_o = {}, {}
            flat_p, tdef = jax.tree_util.tree_flatten(params)
            flat_g = tdef.flatten_up_to(g)
            flat_m = tdef.flatten_up_to(opt)
            outp, outm = [], []
            for p, gg, m in zip(flat_p, flat_g, flat_m):
                m = 0.9 * m + 0.1 * gg
                outp.append(p - lr * m)
                outm.append(m)
            return (jax.tree_util.tree_unflatten(tdef, outp),
                    jax.tree_util.tree_unflatten(tdef, outm), loss)

        opt = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        losses = []
        for s in range(steps):
            idx = rng.choice(len(pairs), size=min(bs, len(pairs)),
                             replace=False)
            ta, ma = self._batch([pairs[i][0] for i in idx])
            tb, mb = self._batch([pairs[i][1] for i in idx])
            self.params, opt, loss = step(self.params, opt, ta, ma, tb, mb)
            losses.append(float(loss))
        return losses
