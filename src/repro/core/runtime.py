"""StorInfer Runtime (§3.4, Fig 2): parallel vector search + LLM inference
with hit-cancellation.

On each query the runtime concurrently
  (a) embeds the query and searches the precomputed store (CPU/storage
      resources — a thread here; a dedicated mesh slice at pod scale), and
  (b) starts LLM inference (chunked decode on the accelerator).
If (a) returns a match with similarity >= S_th_Run, the stored response is
returned immediately and a termination signal cancels (b) at the next chunk
boundary — a miss therefore costs exactly the plain-LLM latency (the decode
ran unimpeded the whole time).

Two runtimes share that structure:

  StorInferRuntime — the paper's one-query-at-a-time race (kept as the
      reference implementation and the sequential benchmark baseline).
  BatchedRuntime   — the serving path. Its async front door
      (``serve``/``submit``) is the stage-decoupled
      ``serving.scheduler.ServingPipeline``: admit → embed+search →
      hit-resolve → decode → write-back, each stage its own worker behind
      a bounded queue. Hits resolve the moment the MIPS search returns;
      misses flow into one persistent continuous-batching
      ``BatchScheduler`` whose freed slots are refilled between waves;
      §3.1 ``add_misses`` write-back + ``flush_and_rebuild`` run off the
      critical path with the index swapped atomically. ``query_batch``
      stays as the synchronous compatibility path over the same stage
      helpers (one embed + one MIPS dispatch + one batched decode racing
      it, hit slots cancelled mid-flight).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional, Sequence, Union


@dataclasses.dataclass
class QueryResult:
    response: str
    source: str               # "store" | "llm"
    hit: bool
    score: float
    matched_query: Optional[str]
    search_s: float
    llm_s: float
    latency_s: float
    chunks_run: int = 0
    cancelled: bool = False   # an LLM decode was started and hit-cancelled


@dataclasses.dataclass
class RuntimeCfg:
    s_th_run: float = 0.9
    parallel: bool = True
    add_misses: bool = False   # §3.1: optionally add new pairs on miss


class StorInferRuntime:
    def __init__(self, index, store, embedder, engine=None,
                 cfg: RuntimeCfg = None):
        """index: FlatIndex/IVFIndex/ShardedIndex over store embeddings;
        store: PrecomputedStore; engine: serving.Engine or None (search-only
        mode returns misses without LLM fallback)."""
        self.index = index
        self.store = store
        self.embedder = embedder
        self.engine = engine
        self.cfg = cfg or RuntimeCfg()
        self._pool = ThreadPoolExecutor(max_workers=2)

    # -- the search half ------------------------------------------------------
    def _search_emb(self, text: str):
        """Score + row + the query embedding (threaded through so the
        §3.1 write-back path never re-encodes what search already did)."""
        t0 = time.perf_counter()
        e = self.embedder.encode([text])
        v, i = self.index.search(e, 1)
        dt = time.perf_counter() - t0
        return float(v[0, 0]), int(i[0, 0]), e, dt

    def search(self, text: str):
        score, row, _, dt = self._search_emb(text)
        return score, row, dt

    # -- full parallel query path ----------------------------------------------
    def query(self, text: str, *, max_new: int = 32,
              temperature=None) -> QueryResult:
        t0 = time.perf_counter()
        fut = self._pool.submit(self._search_emb, text)

        session = None
        if self.engine is not None:
            session = self.engine.start_session(text, max_new=max_new,
                                                temperature=temperature)

        score = row = emb = search_s = None
        while session is not None and not session.done:
            if fut.done():
                score, row, emb, search_s = fut.result()
                if score >= self.cfg.s_th_run:
                    session.cancel()         # Fig 2 termination signal
                break                        # miss: decode continues below
            session.step_chunk()
        if score is None:                    # session won the race (or none)
            score, row, emb, search_s = fut.result()

        if score >= self.cfg.s_th_run:
            mq, resp = self.store.get_pair(row)
            return QueryResult(
                response=resp, source="store", hit=True, score=score,
                matched_query=mq, search_s=search_s,
                llm_s=(session.decode_s + session.prefill_s) if session
                else 0.0,
                latency_s=time.perf_counter() - t0,
                chunks_run=session.chunks_run if session else 0,
                cancelled=bool(session is not None and session.cancelled))

        # miss: let the LLM finish (it kept decoding the whole time)
        llm_text = ""
        if session is not None:
            while not session.done:
                session.step_chunk()
            llm_text = session.text()
            if self.cfg.add_misses:
                # the race's search already encoded this query — reuse it
                self.store.add_batch(emb, [text], [llm_text])
        return QueryResult(
            response=llm_text, source="llm", hit=False, score=score,
            matched_query=None, search_s=search_s,
            llm_s=(session.decode_s + session.prefill_s) if session else 0.0,
            latency_s=time.perf_counter() - t0,
            chunks_run=session.chunks_run if session else 0)

    # -- batched search (benchmarks) --------------------------------------------
    def search_batch(self, texts, k: int = 1):
        t0 = time.perf_counter()
        e = self.embedder.encode(list(texts))
        v, i = self.index.search(e, k)
        return v, i, time.perf_counter() - t0

    def close(self):
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "StorInferRuntime":
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# Batched serving runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedRuntimeCfg:
    s_th_run: float = 0.9
    max_batch: int = 32        # microbatch ceiling for the admission queue
    max_wait_s: float = 0.005  # admission window after the first arrival
    add_misses: bool = False   # §3.1 write-back of fresh (query, response)
    rebuild_every: int = 256   # write-backs between flush + index rebuild
    engine_slots: Optional[int] = None  # sync-path decode slots
    #                                     (None: one per query in the batch)
    # -- ServingPipeline knobs (the serve()/submit() front door) ----------
    decode_slots: int = 4      # persistent continuous-batching slot count
    queue_depth: int = 64      # per-stage bounded queue depth (backpressure)
    async_writeback: bool = True   # §3.1 write-back + rebuild off the
    #                                critical path on a background worker


@dataclasses.dataclass
class RuntimeStats:
    """Serving counters; ``llm_cancelled`` is the hit-cancellation
    accounting — decodes that were started and then killed by a store hit."""
    queries: int = 0
    hits: int = 0
    misses: int = 0
    llm_cancelled: int = 0
    batches: int = 0
    writebacks: int = 0
    index_rebuilds: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.queries if self.queries else 0.0


class BatchedRuntime:
    """Batched StorInfer serving over the staged pipeline.

    The async front door (``serve``/``submit``) runs the stage-decoupled
    ``ServingPipeline``: hits resolve at search time, misses decode on a
    persistent continuous-batching scheduler, write-backs rebuild the
    index in the background. ``query_batch`` is the synchronous
    compatibility path: one embed + one MIPS search + one batched decode
    racing it, hit slots cancelled mid-flight — same stage helpers, with
    a barrier at the end.

    ``index`` may be any of FlatIndex/IVFIndex/ShardedIndex; use
    ``BatchedRuntime.from_store`` to let ``auto_index`` pick the tier.
    ``engine=None`` runs search-only (misses return empty responses).
    """

    def __init__(self, index, store, embedder, engine=None,
                 cfg: BatchedRuntimeCfg = None, mesh=None,
                 auto_index_kw: Optional[dict] = None, rebuild=None):
        """``rebuild``: optional ``(store, mesh) -> index`` callable used
        by ``flush_and_rebuild`` instead of ``auto_index`` — callers that
        pinned a specific tier (the facade's declarative cfg) use it to
        keep write-back rebuilds on that tier."""
        self.index = index
        self.store = store
        self.embedder = embedder
        self.engine = engine
        self.cfg = cfg or BatchedRuntimeCfg()
        self.mesh = mesh
        self._auto_index_kw = dict(auto_index_kw or {})
        self._rebuild = rebuild
        self.stats = RuntimeStats()
        self._pool = ThreadPoolExecutor(max_workers=2)
        self._pipeline = None
        self._last_pipeline = None       # stats survive stop_serving()
        self._pipeline_lock = threading.Lock()
        self._stats_lock = threading.Lock()    # pipeline workers + sync path
        self._index_lock = threading.Lock()    # atomic index swap vs search
        self._wb_lock = threading.Lock()       # write-back accounting
        self._rebuild_lock = threading.Lock()  # one rebuild at a time
        self._pending_writebacks = 0

    @classmethod
    def from_store(cls, store, embedder, engine=None,
                   cfg: BatchedRuntimeCfg = None, mesh=None,
                   cache_dir=None, **auto_index_kw) -> "BatchedRuntime":
        """``cache_dir`` enables the persisted-IVF path: ``"store"`` uses
        the store's own root (the offline pipeline saves its index there),
        any other path is used as-is. Reopening a paper-scale store then
        loads the k-means product instead of refitting it; periodic
        ``flush_and_rebuild`` refreshes the same cache as the store grows
        (the stale row count forces a rebuild + re-save)."""
        from repro.core.index import auto_index
        if cache_dir is not None:
            auto_index_kw["cache_dir"] = str(
                store.root if cache_dir == "store" else cache_dir)
        return cls(auto_index(store, mesh, **auto_index_kw), store,
                   embedder, engine, cfg=cfg, mesh=mesh,
                   auto_index_kw=auto_index_kw)

    # -- the search half (stage 2 of the pipeline) ----------------------------
    def _search_batch(self, texts: List[str]):
        t0 = time.perf_counter()
        embs = self.embedder.encode(texts)
        with self._index_lock:
            index = self.index      # snapshot: rebuilds swap atomically;
        #                             an in-flight search keeps the old one
        v, i = index.search(embs, 1)
        return v[:, 0], i[:, 0], embs, time.perf_counter() - t0

    # -- synchronous batched query path ---------------------------------------
    def query_batch(self, texts: Sequence[str], *,
                    max_new: Union[int, Sequence[int]] = 32,
                    temperature=None) -> List[QueryResult]:
        """The synchronous compatibility path: the whole batch returns
        together, but each ``QueryResult`` carries ITS OWN resolve time —
        hits are stamped when the search returned (the moment the staged
        pipeline would have resolved them), misses when their decode slot
        retired — so latency percentiles computed from a batch are real,
        not one batch-wide number repeated."""
        texts = list(texts)
        if not texts:
            return []
        t0 = time.perf_counter()
        fut = self._pool.submit(self._search_batch, texts)

        session = None
        if self.engine is not None:
            session = self.engine.start_batch_session(
                texts, max_new=max_new, temperature=temperature,
                batch_size=self.cfg.engine_slots)

        # race: batched decode vs batched search (Fig 2, amortized)
        search = None
        while session is not None and not session.done:
            if fut.done():
                search = fut.result()
                for qi, s in enumerate(search[0]):
                    if s >= self.cfg.s_th_run:
                        session.cancel(qi)   # termination signal per slot
                break                        # misses keep decoding below
            session.step_chunk()
        if search is None:
            search = fut.result()
        t_searched = time.perf_counter()     # hits are resolvable NOW
        scores, rows, embs, search_s = search
        cancelled_rids = set()
        reqs = {}
        if session is not None:
            session.run()                    # only miss slots still live
            # a cancel only saved decode work if the request had actually
            # entered a decode wave (slot assigned); cancelled-while-waiting
            # or finished-before-cancel don't count
            reqs = {r.rid: r for r in session.results()}
            cancelled_rids = {rid for rid, r in reqs.items()
                              if r.cancelled and r.slot >= 0}

        results: List[QueryResult] = []
        miss_idx: List[int] = []
        llm_s = session.decode_s if session is not None else 0.0
        hit_latency = t_searched - t0
        for qi, text in enumerate(texts):
            score = float(scores[qi])
            req = reqs.get(qi)
            chunks = req.chunks if req is not None else 0
            if score >= self.cfg.s_th_run:
                mq, resp = self.store.get_pair(int(rows[qi]))
                results.append(QueryResult(
                    response=resp, source="store", hit=True, score=score,
                    matched_query=mq, search_s=search_s, llm_s=llm_s,
                    latency_s=hit_latency, chunks_run=chunks,
                    cancelled=qi in cancelled_rids))
            else:
                miss_idx.append(qi)
                resp = session.text(qi) if session is not None else ""
                done = (req.t_done if req is not None and req.t_done
                        else t_searched)
                results.append(QueryResult(
                    response=resp, source="llm", hit=False, score=score,
                    matched_query=None, search_s=search_s, llm_s=llm_s,
                    latency_s=done - t0, chunks_run=chunks))

        n_hits = len(texts) - len(miss_idx)
        with self._stats_lock:
            self.stats.queries += len(texts)
            self.stats.hits += n_hits
            self.stats.misses += len(miss_idx)
            self.stats.batches += 1
            self.stats.llm_cancelled += len(cancelled_rids)

        if (self.cfg.add_misses and session is not None and miss_idx):
            import numpy as np
            self._writeback(np.asarray(embs)[miss_idx],
                            [texts[qi] for qi in miss_idx],
                            [results[qi].response for qi in miss_idx])
        return results

    # -- §3.1 write-back + rebuild (stage 5 of the pipeline) ------------------
    def _writeback(self, embs, texts, responses):
        """Append fresh (query, response) pairs and trigger the periodic
        flush + rebuild. Called synchronously by ``query_batch`` and from
        the pipeline's background write-back worker."""
        import numpy as np
        with self._wb_lock:
            self.store.add_batch(np.asarray(embs), list(texts),
                                 list(responses))
            with self._stats_lock:
                self.stats.writebacks += len(texts)
            self._pending_writebacks += len(texts)
            need = self._pending_writebacks >= self.cfg.rebuild_every
        if need:
            self.flush_and_rebuild()

    def flush_and_rebuild(self):
        """Persist pending write-backs and rebuild the index over the grown
        store, then SWAP it atomically under the index lock — searches in
        flight keep their snapshot, later ones see the new index. With the
        default ``auto_index`` path the tier is re-picked, so a store that
        outgrew the flat boundary comes back as IVF (or Sharded on a
        mesh); a ``rebuild`` callable pins the caller's choice instead."""
        with self._rebuild_lock:
            self.store.flush()
            if self._rebuild is not None:
                new_index = self._rebuild(self.store, self.mesh)
            else:
                from repro.core.index import auto_index
                new_index = auto_index(self.store, self.mesh,
                                       **self._auto_index_kw)
            with self._index_lock:
                self.index = new_index
            with self._stats_lock:
                self.stats.index_rebuilds += 1
            with self._wb_lock:
                self._pending_writebacks = 0

    # -- async admission (the serving front door) -----------------------------
    def serve(self):
        """Start (or return) the staged ServingPipeline. Safe to call from
        many threads — ``submit`` races here on first use, and two
        pipelines would interleave reads on the shared store handle."""
        from repro.serving.scheduler import ServingPipeline
        with self._pipeline_lock:
            if self._pipeline is None:
                self._pipeline = ServingPipeline(
                    self, max_batch=self.cfg.max_batch,
                    max_wait_s=self.cfg.max_wait_s,
                    queue_depth=self.cfg.queue_depth,
                    decode_slots=self.cfg.decode_slots,
                    async_writeback=self.cfg.async_writeback).start()
                self._last_pipeline = self._pipeline
            return self._pipeline

    def submit(self, text: str, *, max_new: int = 32,
               temperature=None) -> Future:
        """Enqueue one query; a hit resolves the moment its microbatch's
        search returns, a miss when its decode slot retires.
        ``temperature`` applies to the miss decode (the scheduler admits
        same-temperature requests into one wave)."""
        return self.serve().submit(text, max_new=max_new,
                                   temperature=temperature)

    def pipeline_stats(self) -> Optional[dict]:
        """Snapshot of the staged pipeline's accounting (per-stage queue
        depth + wait, hit/miss latency percentiles, decode-slot reuse);
        None if serve() was never started. Survives ``stop_serving``."""
        p = self._pipeline or self._last_pipeline
        return p.stats_snapshot() if p is not None else None

    def stop_serving(self, drain: bool = True):
        """Stop the pipeline (if running) without tearing down the
        runtime — synchronous ``query_batch`` keeps working and ``serve``
        can start a fresh pipeline later."""
        with self._pipeline_lock:
            if self._pipeline is not None:
                self._pipeline.stop(drain=drain)
                self._pipeline = None

    def close(self):
        self.stop_serving()
        self._pool.shutdown(wait=False)

    def __enter__(self) -> "BatchedRuntime":
        return self

    def __exit__(self, *exc):
        self.close()
        return False
