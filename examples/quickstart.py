"""Quickstart: build a precomputed-query store from a knowledge base and
serve queries through the StorInfer runtime.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core.embedder import HashEmbedder
from repro.core.generator import GenCfg, SyntheticOracleLM, chunk_key
from repro.core.index import FlatIndex
from repro.core.kb import build_kb, sample_user_queries
from repro.core.precompute import PrecomputeCfg, PrecomputePipeline
from repro.core.runtime import RuntimeCfg, StorInferRuntime
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer


def main():
    # 1. a knowledge base (stands in for the paper's SQuAD documents)
    kb = build_kb("squad", n_docs=25)
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    emb = HashEmbedder()
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])

    # 2. OFFLINE: batched deduplicated query generation into the store
    #    (checkpointed — a killed build resumes from the manifest)
    with tempfile.TemporaryDirectory() as td:
        store = PrecomputedStore(td, dim=emb.dim)
        pipe = PrecomputePipeline(SyntheticOracleLM(kb), emb, tok,
                                  GenCfg(dedup=True), PrecomputeCfg(wave=32))
        qs, rs, es, stats = pipe.run(chunks, 1500, store=store, seed=0)
        print(f"generated {stats.generated} pairs in {stats.waves} waves "
              f"({stats.discarded} near-duplicates discarded, "
              f"{stats.seconds:.1f}s, {stats.pairs_per_sec:.0f} pairs/s); "
              f"store = "
              f"{store.storage_bytes()['total_bytes'] / 1e6:.2f} MB")

        # 3. ONLINE: queries hit the store or fall through
        rt = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                              engine=None, cfg=RuntimeCfg(s_th_run=0.9))
        user = sample_user_queries(kb, 400, seed=5)
        hits = 0
        for q, fact in user[:400]:
            r = rt.query(q)
            hits += r.hit
        print(f"hit rate @0.9 over {len(user)} user queries: "
              f"{hits / len(user):.3f}")
        r = rt.query(user[0][0])
        print(f"example: {user[0][0]!r}\n  -> [{r.source}] {r.response!r} "
              f"(search {r.search_s * 1e3:.2f} ms)")


if __name__ == "__main__":
    main()
