"""Data pipeline: deterministic, resumable, shard-aware token batches.

Sources:
  * SyntheticLMData — seeded token stream (throughput/dry-run work)
  * TextFileData    — tokenizes a text corpus (the KB documents double as a
                      tiny pretraining corpus for examples/train_small.py)

Both expose ``batch(step) -> {"tokens", "labels"}`` — a PURE function of
(seed, step), so restart-from-checkpoint replays the exact stream from the
saved cursor with no state files (the cursor IS the step). Multi-host: each
host slices [host_id::n_hosts] of the global batch (here: one host).
"""
from __future__ import annotations

import numpy as np


class SyntheticLMData:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.V, self.B, self.S = vocab_size, batch, seq_len
        self.seed = seed

    def batch(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(4, self.V, (self.B, self.S + 1), dtype=np.int64)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class TextFileData:
    def __init__(self, texts, tokenizer, batch: int, seq_len: int,
                 seed: int = 0):
        ids = []
        for t in texts:
            ids.extend(tokenizer.encode(t, eos=True))
        self.ids = np.asarray(ids, np.int32)
        self.B, self.S = batch, seq_len
        self.seed = seed
        self.n_windows = max(len(self.ids) - seq_len - 1, 1)

    def batch(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        starts = rng.integers(0, self.n_windows, self.B)
        toks = np.stack([self.ids[s:s + self.S] for s in starts])
        labs = np.stack([self.ids[s + 1:s + self.S + 1] for s in starts])
        return {"tokens": toks, "labels": labs}
