"""Response-quality metrics (§4): Unigram F1, ROUGE-L F1, BERTScore-proxy.

BERTScore-proxy follows the BERTScore recipe (greedy token-level cosine
matching, precision/recall/F1) with our embedder providing the token
vectors — contextual-BERT weights don't ship in this container; the proxy
preserves the metric's structure and relative ordering.
"""
from __future__ import annotations

import re
from typing import List

import numpy as np

_WORDS = re.compile(r"\w+")


def _toks(s: str) -> List[str]:
    return _WORDS.findall(s.lower())


def unigram_f1(pred: str, ref: str) -> float:
    p, r = _toks(pred), _toks(ref)
    if not p or not r:
        return float(p == r)
    common = {}
    for w in p:
        common[w] = common.get(w, 0) + 1
    overlap = 0
    for w in r:
        if common.get(w, 0) > 0:
            overlap += 1
            common[w] -= 1
    if overlap == 0:
        return 0.0
    prec = overlap / len(p)
    rec = overlap / len(r)
    return 2 * prec * rec / (prec + rec)


def _lcs(a: List[str], b: List[str]) -> int:
    la, lb = len(a), len(b)
    dp = np.zeros((la + 1, lb + 1), np.int32)
    for i in range(la):
        for j in range(lb):
            if a[i] == b[j]:
                dp[i + 1, j + 1] = dp[i, j] + 1
            else:
                dp[i + 1, j + 1] = max(dp[i, j + 1], dp[i + 1, j])
    return int(dp[la, lb])


def rouge_l_f1(pred: str, ref: str) -> float:
    p, r = _toks(pred), _toks(ref)
    if not p or not r:
        return float(p == r)
    l = _lcs(p, r)
    if l == 0:
        return 0.0
    prec, rec = l / len(p), l / len(r)
    return 2 * prec * rec / (prec + rec)


def bert_score_f1(pred: str, ref: str, embedder=None) -> float:
    """Greedy-matching token-cosine F1 with hash token embeddings."""
    from repro.core.embedder import HashEmbedder
    embedder = embedder or HashEmbedder(dim=128, ngrams=(1,))
    p, r = _toks(pred), _toks(ref)
    if not p or not r:
        return float(p == r)
    ep = embedder.encode(p)
    er = embedder.encode(r)
    sim = ep @ er.T                                 # (|p|, |r|)
    prec = float(sim.max(axis=1).mean())
    rec = float(sim.max(axis=0).mean())
    if prec + rec <= 0:
        return 0.0
    return 2 * prec * rec / (prec + rec)


def corpus_mean(metric, preds, refs, **kw) -> float:
    return float(np.mean([metric(p, r, **kw) for p, r in zip(preds, refs)]))
