"""The public API facade: StorInfer build -> open -> query -> query_batch
-> serve round-trips on both flat and IVF tiers, component protocols and
registries, the crash-then-resume build path, and the exported surface of
``repro`` itself (accidental breakage of the public API must fail CI)."""
import dataclasses

import numpy as np
import pytest

import repro
from repro.api import (EngineCfg, EmbedderProtocol, IndexProtocol,
                       StorInfer, SystemCfg, index_caps, make_embedder,
                       make_index, make_pipeline, tier_of)
from repro.core.embedder import HashEmbedder
from repro.core.generator import SyntheticOracleLM
from repro.core.index import FlatIndex, IVFIndex, IncrementalIndex
from repro.core.kb import build_kb, sample_user_queries
from repro.core.precompute import BuildKilled, PrecomputeCfg
from repro.core.runtime import QueryResult, RuntimeStats
from repro.core.store import PrecomputedStore


@pytest.fixture(scope="module")
def kb():
    return build_kb("squad", n_docs=8)


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------


PUBLIC_SURFACE = {
    "StorInfer", "SystemCfg", "EngineCfg", "SystemStats",
    "QueryResult", "RuntimeStats",
    "EmbedderProtocol", "IndexProtocol", "IndexCaps", "index_caps",
    "register_embedder", "register_index",
    "make_embedder", "make_index", "make_pipeline", "tier_of",
}


def test_repro_exports_public_surface():
    """`from repro import X` works for every name in the public API, and
    __all__ advertises exactly that surface."""
    assert set(repro.__all__) == PUBLIC_SURFACE
    for name in PUBLIC_SURFACE:
        assert getattr(repro, name) is not None
    assert repro.StorInfer is StorInfer
    assert repro.QueryResult is QueryResult
    with pytest.raises(AttributeError):
        repro.does_not_exist
    assert PUBLIC_SURFACE <= set(dir(repro))


def test_package_and_api_all_stay_in_sync():
    """The lazy re-export list in repro/__init__ must track api.__all__ —
    a name added to one but not the other is silent surface drift."""
    from repro import api
    assert set(repro.__all__) == set(api.__all__)


def test_result_types_are_the_runtime_ones():
    """One typed result surface: the facade re-exports the same
    QueryResult/RuntimeStats the runtimes produce — not copies."""
    from repro.core import runtime
    assert repro.QueryResult is runtime.QueryResult
    assert repro.RuntimeStats is runtime.RuntimeStats


# ---------------------------------------------------------------------------
# protocols + registries
# ---------------------------------------------------------------------------


def test_embedder_protocol_and_registry():
    emb = make_embedder("hash", dim=64)
    assert isinstance(emb, EmbedderProtocol) and emb.dim == 64
    # instance passthrough is validated too
    assert make_embedder(HashEmbedder()) is not None
    with pytest.raises(TypeError):
        make_embedder(object())
    with pytest.raises(KeyError):
        make_embedder("nope")
    with pytest.raises(ValueError):
        make_embedder("minilm")          # needs tokenizer=


def test_index_protocol_registry_and_caps():
    x = np.random.default_rng(0).normal(size=(64, 16)).astype(np.float32)
    flat = make_index("flat", x)
    ivf = make_index("ivf", x, n_lists=4, nprobe=2)
    assert isinstance(flat, FlatIndex) and isinstance(ivf, IVFIndex)
    for idx in (flat, ivf):
        assert isinstance(idx, IndexProtocol) and len(idx) == 64
        v, i = idx.search(x[:3], 2)
        assert v.shape == (3, 2) and i.shape == (3, 2)
    assert make_index("none", x) is None
    with pytest.raises(KeyError):
        make_index("nope", x)
    with pytest.raises(ValueError):
        make_index("sharded", x)         # needs mesh=
    # capability flags distinguish the tiers behind the shared contract
    assert index_caps(ivf) == repro.IndexCaps(save=True, load=True,
                                              add=False)
    assert index_caps(flat) == repro.IndexCaps(save=False, load=False,
                                               add=False)
    assert index_caps(IncrementalIndex(16)).add
    assert tier_of(flat) == "flat" and tier_of(ivf) == "ivf"
    assert tier_of(None) == "none"


def test_facade_rejects_protocol_violations(tmp_path, kb):
    emb = HashEmbedder()
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    store.add_batch(emb.encode(["q"]), ["q"], ["r"])
    store.flush()
    with pytest.raises(TypeError):
        StorInfer(store, object(), FlatIndex(store.embeddings()))
    with pytest.raises(TypeError):
        StorInfer(store, emb, object())


# ---------------------------------------------------------------------------
# build -> open -> query -> query_batch -> serve round-trips
# ---------------------------------------------------------------------------


def _roundtrip(si, kb, expect_tier):
    assert tier_of(si.index) == expect_tier
    q0, _ = si.store.get_pair(0)
    r = si.query(q0)
    assert isinstance(r, QueryResult)
    assert r.hit and r.source == "store" and r.response
    rs = si.query_batch([q0, "zebra xylophone never stored"])
    assert rs[0].hit and not rs[1].hit
    with si.serve():
        futs = [si.submit(q) for q, _ in sample_user_queries(kb, 4,
                                                             seed=3)]
        futs.append(si.submit(q0))
        assert futs[-1].result(timeout=60).hit
        [f.result(timeout=60) for f in futs]
    s = si.stats()
    assert s.index_tier == expect_tier
    assert s.store_rows == s.index_rows == si.store.count
    assert s.runtime.queries == 1 + 2 + 5
    assert s.runtime.hits + s.runtime.misses == s.runtime.queries
    assert s.store_bytes["total_bytes"] > 0 and not s.has_engine


def test_build_open_roundtrip_flat(tmp_path, kb):
    cfg = SystemCfg()
    with StorInfer.build(kb, cfg, tmp_path / "flat", n_pairs=120) as si:
        assert si.build_stats.generated == 120
        _roundtrip(si, kb, "flat")
    # reopen serves the same store
    with StorInfer.open(tmp_path / "flat", cfg) as si2:
        assert si2.store.count == 120
        _roundtrip(si2, kb, "flat")


def test_build_open_roundtrip_ivf(tmp_path, kb):
    cfg = SystemCfg(index_kw={"flat_max_rows": 64})
    with StorInfer.build(kb, cfg, tmp_path / "ivf", n_pairs=160) as si:
        _roundtrip(si, kb, "ivf")
        # the k-means fit persisted into the store root...
        assert (tmp_path / "ivf" / "index_ivf.npz").exists()
    with StorInfer.open(tmp_path / "ivf", cfg) as si2:
        # ...and reopening LOADED it instead of refitting
        assert si2.index.loaded_from is not None
        _roundtrip(si2, kb, "ivf")


def test_build_kill_resume_and_store_only_mode(tmp_path, kb):
    cfg = SystemCfg(index="none",
                    precompute=PrecomputeCfg(wave=8, checkpoint_every=2))
    with pytest.raises(BuildKilled):
        StorInfer.build(kb, cfg, tmp_path / "s", n_pairs=96,
                        _kill_after_waves=3)
    # the aborted handle committed nothing past the last checkpoint;
    # rerunning the same build resumes and completes
    si = StorInfer.build(kb, cfg, tmp_path / "s", n_pairs=96)
    assert 0 < si.build_stats.resumed_rows < 96
    assert si.store.count == 96
    # index="none" serves nothing — every query path refuses loudly
    for call in (lambda: si.query("x"), lambda: si.query_batch(["x"]),
                 lambda: si.submit("x")):
        with pytest.raises(RuntimeError):
            call()
    assert si.stats().index_tier == "none"
    si.close()


def test_writeback_rebuild_honors_declared_tier(tmp_path, kb):
    """§3.1 write-back rebuilds must rebuild the DECLARED tier with its
    factory kwargs — not hand them to auto_index (which would reject
    e.g. n_lists) or silently re-pick the tier."""
    cfg = SystemCfg(index="ivf", index_kw={"n_lists": 8, "nprobe": 4})
    with StorInfer.build(kb, cfg, tmp_path / "s", n_pairs=64) as si:
        assert tier_of(si.index) == "ivf" and si.index.n_lists == 8
        si._batched.flush_and_rebuild()
        assert tier_of(si._batched.index) == "ivf"
        assert si._batched.index.n_lists == 8
        assert si._batched.stats.index_rebuilds == 1


def test_build_from_raw_chunks_requires_lm(tmp_path, kb):
    from repro.core.generator import chunk_key
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    with pytest.raises(ValueError):
        StorInfer.build(chunks, SystemCfg(), tmp_path / "s", n_pairs=10)
    si = StorInfer.build(chunks, SystemCfg(), tmp_path / "s", n_pairs=10,
                         lm=SyntheticOracleLM(kb))
    assert si.store.count == 10
    si.close()


def test_facade_with_engine_decodes_misses(tmp_path, kb):
    cfg = SystemCfg(engine=EngineCfg(arch="qwen3-1.7b", smoke=True,
                                     max_len=64, chunk=4))
    with StorInfer.build(kb, cfg, tmp_path / "s", n_pairs=40) as si:
        assert si.engine is not None and si.stats().has_engine
        r = si.query("completely unrelated zebra xylophone", max_new=4)
        assert not r.hit and r.source == "llm" and r.response != ""
        q0, _ = si.store.get_pair(0)
        assert si.query(q0, max_new=4).hit


def test_make_pipeline_store_free(kb):
    from repro.core.tokenizer import Tokenizer
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    pipe = make_pipeline(SystemCfg(precompute=PrecomputeCfg(wave=8)),
                         SyntheticOracleLM(kb), tok)
    from repro.core.generator import chunk_key
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    qs, rs, es, stats = pipe.run(chunks, 24, seed=0)
    assert len(qs) == len(rs) == es.shape[0] == 24
    assert stats.generated == 24


def test_s_th_run_convenience_overrides_both_paths():
    cfg = SystemCfg(s_th_run=0.42)
    assert cfg.runtime.s_th_run == 0.42
    assert cfg.batched.s_th_run == 0.42
    # explicit sub-configs win when the convenience knob is unset
    cfg2 = SystemCfg(batched=dataclasses.replace(cfg.batched,
                                                 s_th_run=0.7))
    assert cfg2.batched.s_th_run == 0.7 and cfg2.runtime.s_th_run == 0.9
