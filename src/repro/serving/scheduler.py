"""Admission queue + microbatcher for the batched StorInfer runtime.

Serving millions of users means queries arrive one at a time but must be
*processed* together: one embedding batch, one MIPS search batch through
the index, one LLM dispatch for the misses — the lookup cost amortized
across every in-flight request (cf. triton_distributed's queued async
engine workers). ``MicroBatcher`` is that admission layer:

  submit(item) -> Future        (any thread)
        |                               queue
        v
  worker thread: collect up to ``max_batch`` items, waiting at most
  ``max_wait_s`` after the first arrival, then call
  ``process_batch(items) -> results`` and resolve the futures.

The batcher is transport-agnostic: ``core.runtime.BatchedRuntime`` plugs
its ``query_batch`` in as ``process_batch``; a network frontend would do
the same.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence


@dataclasses.dataclass
class Submission:
    """One queued query and its per-request generation knobs."""
    text: str
    max_new: int = 32
    future: Future = dataclasses.field(default_factory=Future)


@dataclasses.dataclass
class BatcherStats:
    batches: int = 0
    items: int = 0
    max_batch_seen: int = 0

    @property
    def mean_batch(self) -> float:
        return self.items / self.batches if self.batches else 0.0


class MicroBatcher:
    """Drains a submission queue into microbatches on a worker thread.

    ``process_batch`` receives a list of ``Submission`` and must return one
    result per submission (same order). Exceptions fail every future in
    the batch — the callers see the error, the worker keeps serving.
    """

    def __init__(self, process_batch: Callable[[List[Submission]],
                                               Sequence[Any]],
                 *, max_batch: int = 32, max_wait_s: float = 0.005):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._process = process_batch
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.stats = BatcherStats()
        self._q: "queue.Queue[Optional[Submission]]" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._stopping = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._worker is None or not self._worker.is_alive():
            self._stopping = False
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="microbatcher")
            self._worker.start()
        return self

    def stop(self, drain: bool = True):
        """Stop the worker. ``drain=True`` processes what is already
        queued first; otherwise pending futures are cancelled."""
        if self._worker is None:
            return
        if not drain:
            self._stopping = True
            try:
                while True:
                    sub = self._q.get_nowait()
                    if sub is not None:
                        sub.future.cancel()
            except queue.Empty:
                pass
        self._q.put(None)                      # wake + shutdown sentinel
        self._worker.join(timeout=30)
        self._worker = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # -- producer side ------------------------------------------------------
    def submit(self, text: str, *, max_new: int = 32) -> Future:
        if self._worker is None or not self._worker.is_alive():
            raise RuntimeError("MicroBatcher is not running; call start()")
        sub = Submission(text=text, max_new=max_new)
        self._q.put(sub)
        return sub.future

    # -- worker side --------------------------------------------------------
    def _collect(self) -> Optional[List[Submission]]:
        """Block for the first item, then batch what arrives within the
        wait window. Returns None on the shutdown sentinel."""
        first = self._q.get()
        if first is None:
            return None
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            try:
                nxt = (self._q.get_nowait() if remaining <= 0
                       else self._q.get(timeout=remaining))
            except queue.Empty:
                break
            if nxt is None:                     # re-queue sentinel and stop
                self._q.put(None)
                break
            batch.append(nxt)
        return batch

    def _run(self):
        while True:
            batch = self._collect()
            if batch is None:
                return
            # atomically move futures to RUNNING; a False return means the
            # caller cancelled first (and cancel() can no longer succeed
            # afterwards, so set_result below cannot race)
            batch = [s for s in batch
                     if s.future.set_running_or_notify_cancel()]
            if not batch:
                continue
            try:
                results = self._process(batch)
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"process_batch returned {len(results)} results "
                        f"for {len(batch)} submissions")
            except Exception as e:              # noqa: BLE001
                for s in batch:
                    s.future.set_exception(e)
                continue
            self.stats.batches += 1
            self.stats.items += len(batch)
            self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                            len(batch))
            for s, r in zip(batch, results):
                s.future.set_result(r)
