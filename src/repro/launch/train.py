"""Training launcher: any assigned arch on any mesh.

Local CPU (real numerics, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 20

Production posture (on a real v5e pod this is the entry point; XLA flags
for async collectives are set below):
  python -m repro.launch.train --arch llama3.2-3b --steps 1000 \
      --ckpt /ckpts/llama32 [--compress]

Fault tolerance: checkpoints are written asynchronously every
--ckpt-every steps (mesh-agnostic layout), auto-resume picks up the latest,
and restores re-shard elastically onto whatever mesh the surviving job
builds (see training/checkpoint.py + tests/dist_checks.py).
"""
import os

# async-collective / overlap flags for real TPU runs (harmless on CPU)
os.environ.setdefault("LIBTPU_INIT_ARGS", " ".join([
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
]))

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, reduced
from repro.distributed import sharding as Sh
from repro.launch import specs as SP
from repro.launch.mesh import batch_axes_of, make_local_mesh, \
    make_production_mesh
from repro.models import model as M
from repro.training import checkpoint as CK
from repro.training import compression as GC
from repro.training import data as D
from repro.training import optimizer as O
from repro.training import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch (CPU-runnable)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        B, S = args.batch or 4, args.seq or 64
        mesh = None
        run = M.RunCfg(attn_impl="naive", remat=False)
        accum = args.accum or 1
    else:
        B = args.batch or SHAPES["train_4k"].global_batch
        S = args.seq or SHAPES["train_4k"].seq_len
        mesh = make_production_mesh(multi_pod=args.multipod)
        run = SP.make_runcfg(cfg, SHAPES["train_4k"], mesh)
        accum = args.accum or SP.TRAIN_ACCUM.get(args.arch, 1)

    print(f"train {cfg.name}: params~{cfg.param_count() / 1e9:.2f}B "
          f"batch={B}x{S} accum={accum} mesh={mesh and dict(mesh.shape)}")

    compress = None
    if args.compress:
        def compress(grads, opt_state):
            dq, err = GC.compress_grads(grads, opt_state["grad_err"])
            return dq, dict(opt_state, grad_err=err)

    ocfg = O.AdamWCfg(total_steps=args.steps)
    step_fn = T.make_train_step(cfg, run, ocfg, accum=accum,
                                compress=compress)
    data = D.SyntheticLMData(cfg.vocab_size, B, S)

    params = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = O.init(params)
    if args.compress:
        opt["grad_err"] = GC.init_error_state(params)
    if mesh is not None:
        pshard = Sh.param_shardings(params, mesh, cfg)
        params = jax.tree_util.tree_map(jax.device_put, params, pshard)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    ck = CK.Checkpointer(args.ckpt) if args.ckpt else None
    start = 0
    if ck and ck.latest_step() is not None:
        state, meta = ck.restore()
        params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        opt = jax.tree_util.tree_map(jnp.asarray, state["opt"])
        start = meta["step"]
        print(f"resumed at step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step_fn(params, opt, b)
        if (i + 1) % 10 == 0 or i == start:
            print(f"step {i + 1} loss={float(m['loss']):.4f} "
                  f"({(time.time() - t0) / (i - start + 1):.2f}s/step)")
        if ck and (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt})
    if ck:
        ck.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print("done")


if __name__ == "__main__":
    main()
