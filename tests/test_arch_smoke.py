"""Per-architecture smoke tests: reduced configs, one forward / train-grad /
prefill+decode step on CPU, asserting output shapes and no NaNs.

Also checks decode-vs-forward consistency: greedy prefill+decode logits must
match the full-sequence forward logits at the same positions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced, SHAPES
from repro.models import model as M

ARCHS = [a for a in list_configs() if not a.startswith("storinfer-paper")]
RUN = M.RunCfg(attn_impl="naive", remat=False, scan_layers=True,
               moe_impl="scatter", q_block=16, kv_block=16)


def make_batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.rope_kind == "mrope":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        batch["mrope_positions"] = jnp.asarray(pos)
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def test_forward_shapes_no_nan(arch_setup):
    cfg, params = arch_setup
    batch = make_batch(cfg)
    logits, aux = M.forward(cfg, params, batch, RUN)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), cfg.name
    assert not bool(jnp.isnan(aux["moe_aux"]).any())


def test_train_grad_step(arch_setup):
    cfg, params = arch_setup
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.lm_loss(cfg, p, batch, RUN)[0])(params)
    assert np.isfinite(float(loss)), cfg.name
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, cfg.name


def test_prefill_decode_matches_forward(arch_setup):
    cfg, params = arch_setup
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    # full forward over S tokens
    full_logits, _ = M.forward(cfg, params, batch, RUN)

    # prefill S-1 tokens, then decode token S-1; logits must match
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S - 1]
    if "mrope_positions" in batch:
        pre_batch["mrope_positions"] = batch["mrope_positions"][:, :, :S - 1]
    pre_logits, cache = M.prefill(cfg, params, pre_batch, RUN, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, S - 2]),
        rtol=2e-2, atol=2e-2)

    logits, new_cache = M.decode_step(
        cfg, params, batch["tokens"][:, S - 1:S], cache,
        jnp.asarray(S - 1, jnp.int32), RUN)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, S - 1]),
        rtol=2e-2, atol=2e-2)
    # cache shapes preserved
    for a, b in zip(jax.tree_util.tree_leaves(cache),
                    jax.tree_util.tree_leaves(new_cache)):
        assert a.shape == b.shape


def test_blockwise_matches_naive(arch_setup):
    cfg, params = arch_setup
    if cfg.family in ("ssm",):
        pytest.skip("attention-free")
    batch = make_batch(cfg, 2, 32)
    lo_naive, _ = M.forward(cfg, params, batch, RUN)
    lo_block, _ = M.forward(cfg, params, batch,
                            RUN.replace(attn_impl="blockwise"))
    np.testing.assert_allclose(np.asarray(lo_naive), np.asarray(lo_block),
                               rtol=2e-2, atol=2e-2)


def test_param_count_analytic_close(arch_setup):
    cfg, params = arch_setup
    actual = M.count_params(params)
    analytic = cfg.param_count()
    # analytic model ignores norms/bias/router-details: within 5%
    assert abs(actual - analytic) / max(actual, 1) < 0.05, \
        (cfg.name, actual, analytic)
