"""Quantized retrieval path: int8 store shards + scales, the quantized
embedding view, the DeviceStore upload-once/delta-append cache, tier
integration, and the facade's ``quantize`` knob (incl. kill/resume
byte-identity of int8 builds)."""
import numpy as np
import pytest

import jax

from repro.core.store import (PrecomputedStore, QuantizedShardedEmbeddings,
                              dequantize_rows, quantize_rows,
                              roundtrip_dtype)
from repro.core.index import (DeviceStore, FlatIndex, IVFIndex,
                              ShardedIndex, auto_index, device_store_for)


def _rows(n, d=48, seed=0, normalize=True):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    if normalize:
        x /= np.linalg.norm(x, axis=1, keepdims=True)
    return x


# ---------------------------------------------------------------------------
# quantization primitives
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_identity():
    """quant(dequant(quant(x))) == quant(x) bitwise — the property that
    makes tail-shard merges and resumed builds byte-identical."""
    x = _rows(200, normalize=False)
    x[5] = 0.0                       # zero row edge: scale falls back to 1
    q1, s1 = quantize_rows(x)
    q2, s2 = quantize_rows(dequantize_rows(q1, s1))
    assert np.array_equal(q1, q2)
    assert np.array_equal(s1, s2)
    assert q1.dtype == np.int8 and s1.dtype == np.float32
    assert np.abs(q1).max() <= 127
    # error bound: half a quantization step per element
    err = np.abs(dequantize_rows(q1, s1) - x)
    assert np.all(err <= s1[:, None] * 0.5 + 1e-9)


def test_roundtrip_dtype_matches_legacy_float_path():
    x = _rows(64, normalize=False)
    assert np.array_equal(roundtrip_dtype(x, "float16"),
                          x.astype(np.float16).astype(np.float32))
    assert roundtrip_dtype(x, "float32") is not None
    np.testing.assert_array_equal(roundtrip_dtype(x, "float32"), x)
    np.testing.assert_array_equal(
        roundtrip_dtype(x, "int8"), dequantize_rows(*quantize_rows(x)))


# ---------------------------------------------------------------------------
# int8 store format
# ---------------------------------------------------------------------------


def test_int8_store_roundtrip(tmp_path):
    import json
    x = _rows(200)
    st = PrecomputedStore(tmp_path / "s", dim=48, emb_dtype="int8",
                          shard_rows=64)
    for lo in range(0, 200, 37):         # odd batching + mid-build flushes
        hi = min(lo + 37, 200)
        st.add_batch(x[lo:hi], [f"q{i}" for i in range(lo, hi)],
                     [f"r{i}" for i in range(lo, hi)])
        if lo % 2:
            st.flush()
    st.close()

    man = json.loads((tmp_path / "s" / "manifest.json").read_text())
    assert man["emb_dtype"] == "int8"
    assert all("scale_file" in s for s in man["shards"])
    for s in man["shards"]:              # scales on disk, row-aligned
        assert (tmp_path / "s" / s["scale_file"]).exists()
        assert np.load(tmp_path / "s" / s["scale_file"]).shape == \
            (s["rows"],)

    st2 = PrecomputedStore.open_(tmp_path / "s")
    assert st2.quantized
    e = st2.embeddings()
    assert isinstance(e, QuantizedShardedEmbeddings)
    assert e.is_quantized and e.dtype == np.float32
    assert e.shape == (200, 48)
    deq = np.asarray(e)
    _, sc = quantize_rows(x)
    assert np.all(np.abs(deq - x) <= sc[:, None] * 0.5 + 1e-9)
    # view accessors: dequantized on the float surface, raw underneath
    np.testing.assert_array_equal(e[3], deq[3])
    np.testing.assert_array_equal(e[10:20], deq[10:20])
    qv, qs = e.take_q([0, 63, 64, 199])
    assert qv.dtype == np.int8 and qs.dtype == np.float32
    np.testing.assert_array_equal(dequantize_rows(qv, qs),
                                  deq[[0, 63, 64, 199]])
    assert sum(p.shape[0] for p in e.iter_shards()) == 200
    assert all(v.dtype == np.int8 for v, _ in e.iter_qshards())
    # content is the direct per-row quantization of the source rows,
    # independent of add/flush batching
    qv_all, qs_all = st2.embeddings().take_q(np.arange(200))
    qd, sd = quantize_rows(x)
    assert np.array_equal(qv_all, qd) and np.array_equal(qs_all, sd)
    # mmap=False materializes dequantized f32
    np.testing.assert_array_equal(st2.embeddings(mmap=False), deq)
    st2.close()


def test_int8_store_bytes_under_30pct_of_fp32(tmp_path):
    x = _rows(512)
    for dtype in ("int8", "float32"):
        st = PrecomputedStore(tmp_path / dtype, dim=48, emb_dtype=dtype)
        st.add_batch(x, ["q"] * 512, ["r"] * 512)
        st.close()
    b8 = PrecomputedStore.open_(tmp_path / "int8").storage_bytes()
    b32 = PrecomputedStore.open_(tmp_path / "float32").storage_bytes()
    assert b8["index_bytes"] / b32["index_bytes"] <= 0.30
    assert b8["rows"] == b32["rows"] == 512


def test_int8_store_pending_rows_visible(tmp_path):
    """Unflushed rows appear in the quantized view exactly like flushed
    ones (the §3.1 write-back window before the periodic flush)."""
    x = _rows(30)
    st = PrecomputedStore(tmp_path / "s", dim=48, emb_dtype="int8")
    st.add_batch(x[:20], ["q"] * 20, ["r"] * 20)
    st.flush()
    st.add_batch(x[20:], ["q"] * 10, ["r"] * 10)   # pending, no flush
    e = st.embeddings()
    assert e.shape == (30, 48)
    qv, qs = e.take_q(np.arange(30))
    qd, sd = quantize_rows(x)
    assert np.array_equal(qv, qd) and np.array_equal(qs, sd)
    st.close()


# ---------------------------------------------------------------------------
# DeviceStore: upload once, append deltas, scan exactly
# ---------------------------------------------------------------------------


def _int8_store(tmp_path, x, name="s", shard_rows=256):
    st = PrecomputedStore(tmp_path / name, dim=x.shape[1],
                          emb_dtype="int8", shard_rows=shard_rows)
    st.add_batch(x, [f"q{i}" for i in range(len(x))], ["r"] * len(x))
    st.flush()
    return st


def test_device_store_cache_and_delta_append(tmp_path):
    x = _rows(600)
    st = _int8_store(tmp_path, x)
    idx = auto_index(st)
    assert isinstance(idx, FlatIndex)
    dev = idx.dev
    u0 = dev.uploads
    assert dev.n_rows == 600 and dev.quantized
    # rebuild over the same store: cached residency, zero new uploads
    idx2 = auto_index(st)
    assert idx2.dev is dev and dev.uploads == u0
    # store grows (write-back): only the delta ships
    st.add_batch(x[:50], ["nq"] * 50, ["nr"] * 50)
    st.flush()
    idx3 = auto_index(st)
    assert idx3.dev is dev
    assert dev.n_rows == 650 and dev.uploads == u0 + 1
    # shrinking is refused (a different store at the same identity)
    with pytest.raises(ValueError):
        dev.sync(_rows(10))
    st.close()


def test_device_store_search_matches_exact_fp32_of_dequantized(tmp_path):
    """The gemm-layout scan is EXACT over the dequantized rows — the only
    error vs raw fp32 is the quantization itself."""
    x = _rows(500)
    st = _int8_store(tmp_path, x)
    q = _rows(16, seed=5)
    v, i = DeviceStore(st).search(q, 5)
    deq = np.asarray(st.embeddings())
    s = q @ deq.T
    np.testing.assert_allclose(
        v, np.sort(s, axis=1)[:, ::-1][:, :5], rtol=1e-5, atol=1e-6)
    st.close()


def test_device_store_kernel_layout_agrees_with_gemm(tmp_path):
    x = _rows(700)
    st = _int8_store(tmp_path, x)
    q = x[np.random.default_rng(7).integers(0, 700, 32)]
    vg, ig = DeviceStore(st, layout="gemm").search(q, 3)
    vk, ik = DeviceStore(st, layout="kernel").search(q, 3)
    # kernel layout quantizes the QUERY block too; scores agree within
    # the query's own rounding and top-1 identity on serving queries
    np.testing.assert_allclose(vk, vg, atol=5e-3)
    assert (ik[:, 0] == ig[:, 0]).mean() >= 0.99
    st.close()


def test_device_store_fp16_ships_native_and_casts_once(tmp_path):
    """fp16 stores: the resident operand is built once at construction —
    searches run on it directly with no per-batch upcast of the matrix."""
    x = _rows(300)
    st = PrecomputedStore(tmp_path / "s", dim=48, emb_dtype="float16")
    st.add_batch(x, ["q"] * 300, ["r"] * 300)
    st.flush()
    idx = auto_index(st)
    dev = idx.dev
    u0 = dev.uploads
    q = _rows(8, seed=9)
    v, i = idx.search(q, 4)
    v2, i2 = idx.search(q, 4)
    assert dev.uploads == u0          # searching never re-ships anything
    ref = q @ np.asarray(st.embeddings(), np.float32).T
    np.testing.assert_allclose(
        v, np.sort(ref, axis=1)[:, ::-1][:, :4], rtol=1e-3, atol=1e-4)
    # kernel layout keeps the fp16 operand resident AS fp16 (the Pallas
    # dot upcasts in-register; no per-search fp32 copy) and agrees
    devk = DeviceStore(st, layout="kernel")
    import jax.numpy as jnp
    assert devk._x.dtype == jnp.float16
    vk, ik = devk.search(q, 4)
    np.testing.assert_allclose(vk, v, rtol=1e-3, atol=1e-4)
    np.testing.assert_array_equal(ik, i)
    st.close()


def test_ivf_tier_does_not_pin_flat_residency(tmp_path):
    """auto_index at the IVF tier must not create (and permanently cache)
    a full flat device copy just to seed k-means; a residency left over
    from the flat tier IS reused."""
    from repro.core.index import _DEVICE_STORES, cached_device_store
    x = _rows(600, d=32)
    st = _int8_store(tmp_path, x)
    assert cached_device_store(st) is None
    idx = auto_index(st, flat_max_rows=100)       # forces the IVF tier
    assert isinstance(idx, IVFIndex)
    assert cached_device_store(st) is None        # no residency created
    # a flat-tier store that later crosses the boundary reuses its cache
    dev = device_store_for(st)
    assert cached_device_store(st) is dev
    idx2 = auto_index(st, flat_max_rows=100)
    assert isinstance(idx2, IVFIndex)
    assert _DEVICE_STORES.get(st) is dev
    st.close()


def test_device_store_for_keys_on_store_identity(tmp_path):
    x = _rows(100)
    st = _int8_store(tmp_path, x)
    a = device_store_for(st)
    b = device_store_for(st)
    assert a is b
    # raw arrays have no stable identity: fresh instance each time
    assert device_store_for(x) is not device_store_for(x)
    st.close()


# ---------------------------------------------------------------------------
# tiers over quantized views
# ---------------------------------------------------------------------------


def test_int8_flat_recall_parity_vs_fp32(tmp_path):
    x = _rows(1500, d=64)
    rng = np.random.default_rng(3)
    q = x[rng.integers(0, 1500, 64)] \
        + 0.05 * rng.normal(size=(64, 64)).astype(np.float32)
    q = (q / np.linalg.norm(q, axis=1, keepdims=True)).astype(np.float32)
    _, i32 = FlatIndex(x).search(q, 1)
    st = _int8_store(tmp_path, x)
    _, i8 = auto_index(st).search(q, 1)
    assert (i8[:, 0] == i32[:, 0]).mean() >= 0.99
    st.close()


def test_ivf_accepts_quantized_view(tmp_path):
    x = _rows(1200, d=64)
    st = _int8_store(tmp_path, x)
    ivf = IVFIndex(st.embeddings(), n_lists=16, nprobe=8)
    assert ivf.centroids.dtype == np.float32     # coarse probe stays fp32
    rng = np.random.default_rng(4)
    q = x[rng.integers(0, 1200, 32)]
    v, i = ivf.search(q, 5)
    assert v.shape == (32, 5)
    # exact duplicates of stored rows must come back as themselves
    assert (v[:, 0] > 0.98).mean() > 0.9
    st.close()


def test_ivf_save_load_roundtrip_on_quantized_store(tmp_path):
    x = _rows(900, d=64)
    st = _int8_store(tmp_path, x)
    ivf = IVFIndex(st.embeddings(), n_lists=12, nprobe=6)
    ivf.save(tmp_path / "ivf.npz")
    loaded = IVFIndex.load(tmp_path / "ivf.npz", st.embeddings())
    q = _rows(8, d=64, seed=2)
    v1, i1 = ivf.search(q, 3)
    v2, i2 = loaded.search(q, 3)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(i1, i2)
    st.close()


def test_sharded_index_int8_matches_flat(tmp_path):
    from jax.sharding import Mesh
    x = _rows(513, d=64)                  # odd: forces padded rows + mask
    st = _int8_store(tmp_path, x)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    sh = ShardedIndex(st.embeddings(), mesh)
    assert sh.scales is not None and len(sh) == 513
    q = _rows(8, d=64, seed=6)
    vs, is_ = sh.search(q, 5)
    vf, if_ = DeviceStore(st).search(q, 5)
    np.testing.assert_allclose(vs, vf, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(is_, if_)
    st.close()


# ---------------------------------------------------------------------------
# facade integration
# ---------------------------------------------------------------------------


def test_facade_quantize_knob_end_to_end(tmp_path):
    from repro.api import StorInfer, SystemCfg
    from repro.core.kb import build_kb
    kb = build_kb("squad", n_docs=6)
    cfg = SystemCfg(quantize=True, s_th_run=0.9)
    assert cfg.emb_dtype == "int8"
    # emb_dtype spelling implies the knob too
    assert SystemCfg(emb_dtype="int8").quantize
    with StorInfer.build(kb, cfg, tmp_path / "sys", n_pairs=200) as si:
        assert str(si.store.emb_dtype) == "int8"
        q0 = si.store.get_pair(0)[0]
        r = si.query(q0)
        assert r.hit and r.score >= 0.99
        rs = si.query_batch([q0, "completely novel zebra question"])
        assert rs[0].hit and not rs[1].hit
        with si.serve():
            assert si.submit(q0).result(timeout=30).hit
        sb = si.stats().store_bytes
        assert sb["index_bytes"] < 200 * 384 * 1.5   # int8-ish, not fp32
    # reopen honors the manifest dtype regardless of cfg
    with StorInfer.open(tmp_path / "sys", SystemCfg(s_th_run=0.9)) as si2:
        assert si2.store.quantized
        assert si2.query(q0).hit


def test_facade_rebuild_reuses_device_residency(tmp_path):
    from repro.api import StorInfer, SystemCfg
    from repro.core.kb import build_kb
    kb = build_kb("squad", n_docs=6)
    cfg = SystemCfg(quantize=True, s_th_run=0.9)
    with StorInfer.build(kb, cfg, tmp_path / "sys", n_pairs=150) as si:
        dev = si.index.dev
        n0, u0 = dev.n_rows, dev.uploads
        e = si.embedder.encode(["fresh writeback query"])
        si.store.add_batch(e, ["fresh writeback query"], ["resp."])
        si._batched.flush_and_rebuild()
        assert si._batched.index.dev is dev      # cached, not re-uploaded
        assert dev.n_rows == n0 + 1 and dev.uploads == u0 + 1
        v, i = si._batched.index.search(e, 1)
        assert int(i[0, 0]) == n0 and v[0, 0] > 0.99


def test_int8_build_kill_resume_byte_identical(tmp_path):
    """The precompute pipeline's resume byte-identity holds for quantized
    stores (per-row quantization + the store-dtype dedup round-trip)."""
    from repro.api import StorInfer, SystemCfg
    from repro.core.kb import build_kb
    from repro.core.precompute import BuildKilled, PrecomputeCfg
    kb = build_kb("squad", n_docs=5)
    cfg = SystemCfg(quantize=True, index="none",
                    precompute=PrecomputeCfg(wave=8, checkpoint_every=2))
    with StorInfer.build(kb, cfg, tmp_path / "full", n_pairs=120) as full:
        assert full.store.count == 120
    with pytest.raises(BuildKilled):
        StorInfer.build(kb, cfg, tmp_path / "killed", n_pairs=120,
                        _kill_after_waves=4)
    with StorInfer.build(kb, cfg, tmp_path / "killed",
                         n_pairs=120) as resumed:
        assert resumed.store.count == 120
    for name in sorted(p.name for p in (tmp_path / "full").glob("emb_*")) \
            + ["text.jsonl", "offsets.npy"]:
        a = (tmp_path / "full" / name).read_bytes()
        b = (tmp_path / "killed" / name).read_bytes()
        assert a == b, f"{name} differs between full and resumed build"
