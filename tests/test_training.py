"""Training substrate: loss decreases, checkpoint/restart equivalence,
gradient compression error-feedback invariant, jaxpr cost counter."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.training import checkpoint as CK
from repro.training import compression as GC
from repro.training import data as D
from repro.training import optimizer as O
from repro.training import train as T

RUN = M.RunCfg(attn_impl="naive", remat=False)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = dataclasses.replace(reduced(get_config("qwen3-1.7b")),
                              n_layers=2, vocab_size=256)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    data = D.SyntheticLMData(cfg.vocab_size, batch=4, seq_len=32, seed=0)
    ocfg = O.AdamWCfg(lr=3e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(T.make_train_step(cfg, RUN, ocfg))
    return cfg, params, data, step


def _run(params, step, data, n, start=0):
    opt = O.init(params)
    losses = []
    for i in range(start, start + n):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    return params, opt, losses


def test_loss_decreases(tiny_setup):
    cfg, params, data, step = tiny_setup
    # overfit a single repeated batch — loss must drop markedly
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = O.init(params)
    first = last = None
    p = params
    for i in range(30):
        p, opt, m = step(p, opt, b)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.8, (first, last)


def test_grad_accum_matches_single_batch(tiny_setup):
    cfg, params, data, _ = tiny_setup
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    ocfg = O.AdamWCfg(lr=1e-3, clip_norm=0.0)
    s1 = jax.jit(T.make_train_step(cfg, RUN, ocfg, accum=1))
    s2 = jax.jit(T.make_train_step(cfg, RUN, ocfg, accum=2))
    p1, _, m1 = s1(params, O.init(params), b)
    p2, _, m2 = s2(params, O.init(params), b)
    for a, c in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_checkpoint_restart_equivalence(tiny_setup, tmp_path):
    """train(10) == train(5) -> save -> restore -> train(5)."""
    cfg, params, data, step = tiny_setup
    pA, optA, _ = _run(params, step, data, 10)

    pB, optB, _ = _run(params, step, data, 5)
    ck = CK.Checkpointer(tmp_path / "ck")
    ck.save(5, {"params": pB, "opt": optB}, blocking=True)
    state, meta = ck.restore()
    pC = jax.tree_util.tree_map(jnp.asarray, state["params"])
    optC = jax.tree_util.tree_map(jnp.asarray, state["opt"])
    optC["step"] = jnp.asarray(optC["step"], jnp.int32)
    for i in range(5, 10):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        pC, optC, _ = step(pC, optC, b)
    for a, c in zip(jax.tree_util.tree_leaves(pA),
                    jax.tree_util.tree_leaves(pC)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_checkpoint_crash_safety(tmp_path):
    ck = CK.Checkpointer(tmp_path / "ck")
    ck.save(1, {"x": np.arange(4)}, blocking=True)
    # a stale .tmp dir from a "crash" must not be believed
    (tmp_path / "ck" / "step_00000002.tmp").mkdir()
    assert ck.latest_step() == 1
    state, _ = ck.restore()
    np.testing.assert_array_equal(state["x"], np.arange(4))


def test_error_feedback_invariant():
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
              for _ in range(3)]
    err = [jnp.zeros((32, 8), jnp.float32) for _ in range(3)]
    applied = [jnp.zeros((32, 8), jnp.float32) for _ in range(3)]
    for _ in range(10):
        dq, err = GC.compress_grads(g_true, err)
        applied = [a + d for a, d in zip(applied, dq)]
    # sum(applied) == 10 * g_true - residual, residual bounded by one quantum
    for a, g, e in zip(applied, g_true, err):
        np.testing.assert_allclose(np.asarray(a + e), np.asarray(10 * g),
                                   rtol=1e-4, atol=1e-4)


def test_jaxpr_costs_exact_on_known_program():
    from repro.launch.costs import fn_costs

    def f(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = fn_costs(f, xs, ws)
    assert c["flops"] == 4 * 2 * 64 * 64 * 64, c["flops"]


def test_data_pipeline_deterministic_and_resumable():
    d = D.SyntheticLMData(100, 4, 16, seed=3)
    b1 = d.batch(7)
    b2 = d.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(8)["tokens"], b1["tokens"])
