"""Async, mesh-agnostic checkpointing with elastic re-shard on restore.

Layout (step_NNNNNNNN/):
  meta.json          — step, flat key list, shapes/dtypes, data cursor
  <flat-key>.npy     — one array per leaf (fully materialized, mesh-agnostic)

Design points for 1000+-node deployments (adapted to this container's
single-process runtime; the multi-host notes are in README §Runbook):

* **Mesh-agnostic layout** — leaves are saved as GLOBAL arrays keyed by
  pytree path, never by device. Restoring onto a different mesh shape (the
  elastic-scaling path: lose a pod, re-shard onto the survivors) is just
  ``device_put`` with the new sharding — exercised by
  tests/test_checkpoint.py::test_elastic_reshard.
* **Async** — ``save`` snapshots to host memory synchronously (cheap:
  device->host copy) and writes to disk on a background thread, so the
  train loop resumes immediately; ``wait()`` joins before the next save or
  exit. Multi-host: each host writes its addressable shards; here that
  degenerates to one writer.
* **Atomicity / crash-equivalence** — writes go to ``<dir>.tmp`` then
  ``os.replace`` (atomic rename); a crash mid-write leaves the previous
  checkpoint intact. ``latest_step`` only believes directories with a
  complete ``meta.json``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(path + (str(k),), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(path + (str(i),), v)
        else:
            flat["/".join(path)] = node

    rec((), tree)
    return flat


def _unflatten(flat: Dict[str, Any]):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class Checkpointer:
    def __init__(self, root, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: dict, extra: Optional[dict] = None,
             blocking: bool = False):
        """state: pytree of jax/np arrays. Device->host copy happens NOW;
        disk write happens on a background thread (async checkpointing)."""
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {"step": step, "extra": extra or {},
                "keys": {k: [list(a.shape), str(a.dtype)]
                         for k, a in host.items()}}

        def write():
            final = self.root / f"step_{step:08d}"
            tmp = Path(str(final) + ".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for k, a in host.items():
                np.save(tmp / (k.replace("/", "__") + ".npy"), a)
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def steps(self):
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "meta.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Returns (state, meta). ``shardings``: optional pytree of
        NamedShardings — THE elastic re-shard path: pass shardings built on
        the CURRENT mesh (any shape) and every leaf is device_put to it."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self.root / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        flat = {}
        for k in meta["keys"]:
            flat[k] = np.load(d / (k.replace("/", "__") + ".npy"))
        state = _unflatten(flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, meta
