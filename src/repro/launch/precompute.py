"""Offline precompute launcher: paper-scale store builds (§3.2/§3.3).

  PYTHONPATH=src python -m repro.launch.precompute \
      --dataset squad --n-pairs 150000 --wave 32 --store runs/squad150k

Builds (or resumes — the default when the store directory already holds a
checkpointed build) a deduplicated precomputed-query store via the batched
``PrecomputePipeline``, then fits and persists the serving index into the
store root so ``BatchedRuntime.from_store(..., cache_dir="store")`` reopens
it without re-running k-means. Kill it any time: rerunning the same command
continues from the last checkpoint and produces a store byte-identical to
an uninterrupted run.
"""
import argparse
import time

from repro.core.embedder import HashEmbedder, MiniLMEncoder
from repro.core.generator import GenCfg, SyntheticOracleLM, chunk_key
from repro.core.index import auto_index, select_tier
from repro.core.kb import build_kb
from repro.core.precompute import (PrecomputeCfg, PrecomputePipeline,
                                   STATE_KEY)
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="squad",
                    choices=("squad", "narrativeqa", "triviaqa"))
    ap.add_argument("--n-docs", type=int, default=None,
                    help="KB size (default: dataset profile)")
    ap.add_argument("--n-pairs", type=int, default=150_000,
                    help="target deduplicated pairs (paper: 150K)")
    ap.add_argument("--wave", type=int, default=32,
                    help="candidates per batched step")
    ap.add_argument("--store", required=True, help="store directory")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-every", type=int, default=64,
                    help="waves between resume checkpoints")
    ap.add_argument("--background-recluster", action="store_true",
                    help="refit the dedup IVF in a thread (faster, gives "
                         "up kill/resume determinism)")
    ap.add_argument("--embedder", choices=("hash", "minilm"),
                    default="hash")
    ap.add_argument("--fresh", action="store_true",
                    help="refuse to resume; store dir must be empty")
    ap.add_argument("--no-index", action="store_true",
                    help="skip fitting + persisting the serving index")
    args = ap.parse_args(argv)

    kb = build_kb(args.dataset, seed=args.seed, n_docs=args.n_docs)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    emb = HashEmbedder() if args.embedder == "hash" else MiniLMEncoder(tok)
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]

    try:
        store = PrecomputedStore.open_(args.store)
        done = store.manifest_extra.get(STATE_KEY, {}).get("generated", "?")
        print(f"resuming store {args.store}: {store.count} rows "
              f"(checkpoint says {done})")
    except FileNotFoundError:
        store = PrecomputedStore(args.store, dim=emb.dim)
        print(f"fresh store {args.store}")

    pipe = PrecomputePipeline(
        SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True),
        PrecomputeCfg(wave=args.wave,
                      checkpoint_every=args.checkpoint_every,
                      background_recluster=args.background_recluster))

    t0 = time.perf_counter()
    last = [t0]

    def on_wave(waves, generated, discarded, mode):
        if time.perf_counter() - last[0] >= 5.0:
            last[0] = time.perf_counter()
            rate = generated / (time.perf_counter() - t0 + 1e-9)
            print(f"  wave {waves}: {generated}/{args.n_pairs} pairs "
                  f"({discarded} discarded, dedup={mode}, "
                  f"{rate:.0f} pairs/s this run)")

    _, _, _, stats = pipe.run(chunks, args.n_pairs, store=store,
                              seed=args.seed, resume=not args.fresh,
                              on_wave=on_wave)
    sb = store.storage_bytes()
    print(f"build done: {store.count} rows "
          f"({stats.generated} this run, {stats.discarded} discarded, "
          f"{stats.pairs_per_sec:.0f} pairs/s, "
          f"dedup index ended {stats.index_mode}); "
          f"store {sb['total_bytes'] / 1e6:.1f} MB "
          f"({sb['index_bytes'] / 1e6:.1f} embeddings + "
          f"{sb['metadata_bytes'] / 1e6:.1f} metadata)")

    if not args.no_index:
        tier = select_tier(store.count)
        t1 = time.perf_counter()
        idx = auto_index(store, cache_dir=store.root)
        how = "loaded" if getattr(idx, "loaded_from", None) else "built"
        print(f"serving index: {tier} {how} in "
              f"{time.perf_counter() - t1:.1f}s "
              f"(cache: {store.root}/index_ivf.npz)"
              if tier == "ivf" else
              f"serving index: {tier} ({time.perf_counter() - t1:.1f}s; "
              "nothing to cache below the IVF boundary)")
    store.close()


if __name__ == "__main__":
    main()
