"""Deduplicated query generation (§3.2): the paper's Generator.

Two techniques, implemented exactly as described:

* **Adaptive Query Masking** — recently generated queries are injected back
  into the generation context. Candidates are taken most-recent-first,
  tokenized, and included only if the WHOLE query fits the remaining token
  budget ``max_ctx - len(chunk) - len(scaffold)``.

* **Adaptive Sampling** — a candidate whose embedding similarity to any
  stored query reaches ``S_th_Gen`` (paper: 0.99) is DISCARDED, and the
  generation temperature steps +0.1 (from 0.7 up to 1.0) to push the next
  samples toward diversity. (The paper increases monotonically on each
  collision; we follow that, tracked per knowledge chunk.)

The LLM behind generation is pluggable:
  * ``SyntheticOracleLM`` — a knowledge-grounded query synthesizer with a
    real temperature-controlled sampling distribution over (fact, template,
    filler) — semantically meaningful queries without pretrained weights,
    used for the paper-reproduction benchmarks.
  * ``TinyJaxLM`` (repro.serving.lm) — an actual JAX LM driven through the
    serving engine (prompt -> sample -> detokenize); mechanically identical
    path, used by tests/examples to prove the plumbing is LLM-real.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.kb import KB, TEMPLATES, FILLERS, render_query


@dataclasses.dataclass
class GenCfg:
    s_th_gen: float = 0.99
    temp0: float = 0.7
    temp_step: float = 0.1
    temp_max: float = 1.0
    max_ctx: int = 512            # generator LM context length (tokens)
    scaffold_tokens: int = 32     # prompt scaffolding budget
    dedup: bool = True            # False = the paper's "Random" baseline
    mask_recent: int = 64         # masking candidate pool (most recent)


class QueryLM(Protocol):
    def generate_query(self, chunk_text: str, masked: Sequence[str],
                       temperature: float, rng) -> str: ...

    def answer(self, query: str, chunk_text: str) -> str: ...


class SyntheticOracleLM:
    """Knowledge-grounded generator with temperature-controlled diversity.

    Models an LLM prompted to "ask questions a user would ask about this
    document": at the default temperature (0.7) its (fact, template)
    distribution matches the user-query distribution shape (the paper's
    predictable-queries premise) — so low temperature re-samples popular
    combos (many near-duplicates, the regime adaptive sampling fights) and
    HIGHER temperature flattens the same distribution (on-distribution
    diversity, not noise). Filler phrasing is sampled like users do,
    independent of temperature. Masked queries are avoided (an
    instruction-following LLM told "don't repeat these").
    """

    def __init__(self, kb: KB, quality: str = "8b"):
        self.kb = kb
        self.quality = quality
        self._doc_facts = {d.doc_id: d.facts for d in kb.docs}
        # per-doc base log-probs from the shared popularity ranks
        self._doc_logp = {}
        fact_index = {id(f): i for i, f in enumerate(kb.facts)}
        for d in kb.docs:
            ranks = np.asarray([kb.popularity[fact_index[id(f)]]
                                for f in d.facts], np.float64)
            self._doc_logp[d.doc_id] = -kb.zipf_a * np.log(ranks + 1.0)
        self._t_logp = -kb.template_skew * np.log(
            np.arange(1, len(TEMPLATES) + 1, dtype=np.float64))

    def generate_query(self, chunk_text, masked, temperature, rng):
        doc_id = int(chunk_text.split("\x00", 1)[0])  # chunk key prefix
        facts = self._doc_facts[doc_id]
        t_eff = max(temperature, 0.05) / 0.7   # temp0 == user distribution
        pf = np.exp(self._doc_logp[doc_id] / t_eff)
        pf /= pf.sum()
        pt = np.exp(self._t_logp / t_eff)
        pt /= pt.sum()
        masked_set = set(masked)
        for _ in range(8):  # the LLM "tries again" within one call
            f = facts[rng.choice(len(facts), p=pf)]
            t = int(rng.choice(len(TEMPLATES), p=pt))
            fill = int(rng.choice(len(FILLERS)))
            q = render_query(f, t, fill)
            if q not in masked_set:
                return q
        return q

    def answer(self, query, chunk_text):
        doc_id = int(chunk_text.split("\x00", 1)[0])
        best, score = None, -1
        qw = set(query.lower().split())
        for f in self._doc_facts[doc_id]:
            s = len(qw & set((f.entity + " " + f.relation).split()))
            if s > score:
                best, score = f, s
        if self.quality == "8b":
            return best.answer()
        # "1b" degraded responder: terse, sometimes drops the value detail
        return f"{best.relation}: {best.value.split()[0]}"


def chunk_key(doc_id: int, text: str) -> str:
    """Chunks carry their doc id so oracle LMs can ground answers."""
    return f"{doc_id}\x00{text}"


@dataclasses.dataclass
class GenStats:
    generated: int = 0
    discarded: int = 0
    seconds: float = 0.0
    max_pair_seconds: float = 0.0
    temp_final: float = 0.0


def masked_for_chunk(tok, cfg: GenCfg, recent: Sequence[str],
                     chunk_text: str) -> List[str]:
    """Adaptive query masking (§3.2): most-recent-first prior queries that
    fit WHOLE in the remaining context budget. Shared by the sequential
    generator and the batched precompute pipeline so their masking
    semantics cannot drift apart."""
    budget = cfg.max_ctx - tok.count(chunk_text) - cfg.scaffold_tokens
    chosen = []
    for q in reversed(recent[-cfg.mask_recent:]):
        n = tok.count(q)
        if n <= budget:              # only COMPLETE prior queries
            chosen.append(q)
            budget -= n
        # (queries that don't fit are skipped, not truncated)
    return chosen


class QueryGenerator:
    """Drives a QueryLM over a knowledge base into a store/index.

    This is the paper's strictly sequential loop — one candidate, one
    ``embedder.encode`` call, one dense dedup scan per step — kept as the
    semantic reference and benchmark baseline. The production offline path
    is ``repro.core.precompute.PrecomputePipeline``, which batches the
    embed + dedup across a wave of candidates (>= 3x pairs/sec at wave 32;
    identical semantics at wave 1).
    """

    def __init__(self, lm: QueryLM, embedder, tokenizer, cfg: GenCfg = None):
        self.lm = lm
        self.embedder = embedder
        self.tok = tokenizer
        self.cfg = cfg or GenCfg()

    # -- adaptive query masking --------------------------------------------
    def select_masked(self, recent: List[str], chunk_text: str) -> List[str]:
        return masked_for_chunk(self.tok, self.cfg, recent, chunk_text)

    # -- main loop ------------------------------------------------------------
    def generate(self, chunks: Sequence[str], n_target: int, *, seed=0,
                 store=None, on_pair=None) -> Tuple[List[str], List[str],
                                                    np.ndarray, GenStats]:
        """Generate up to ``n_target`` accepted (query, response) pairs.

        Returns (queries, responses, embeddings, stats). ``store`` (a
        PrecomputedStore) receives batches as they accept; ``on_pair`` is an
        optional callback(query, response).
        """
        rng = np.random.default_rng(seed)
        cfg = self.cfg
        queries: List[str] = []
        responses: List[str] = []
        embs: List[np.ndarray] = []
        emb_mat: Optional[np.ndarray] = None
        temps = {i: cfg.temp0 for i in range(len(chunks))}
        recent: List[str] = []
        stats = GenStats()
        t_start = time.perf_counter()
        ci = 0
        attempts = 0
        max_attempts = n_target * 20 + 100

        while len(queries) < n_target and attempts < max_attempts:
            attempts += 1
            t0 = time.perf_counter()
            chunk = chunks[ci % len(chunks)]
            ci += 1
            masked = self.select_masked(recent, chunk) if cfg.dedup else []
            temp = temps[(ci - 1) % len(chunks)] if cfg.dedup else cfg.temp0
            q = self.lm.generate_query(chunk, masked, temp, rng)
            e = self.embedder.encode([q])[0]
            if cfg.dedup and emb_mat is not None and len(emb_mat):
                sim = float(np.max(emb_mat @ e))
                if sim >= cfg.s_th_gen:
                    stats.discarded += 1
                    # adaptive sampling: bump temperature, discard
                    key = (ci - 1) % len(chunks)
                    temps[key] = min(temps[key] + cfg.temp_step,
                                     cfg.temp_max)
                    recent.append(q)   # mask it so the LM avoids it next
                    stats.max_pair_seconds = max(
                        stats.max_pair_seconds, time.perf_counter() - t0)
                    continue
            r = self.lm.answer(q, chunk)
            queries.append(q)
            responses.append(r)
            embs.append(e)
            recent.append(q)
            if emb_mat is None:
                emb_mat = e[None, :].copy()
            else:
                emb_mat = np.concatenate([emb_mat, e[None, :]], axis=0)
            if store is not None:
                store.add_batch(e[None, :], [q], [r])
            if on_pair:
                on_pair(q, r)
            stats.generated += 1
            stats.max_pair_seconds = max(stats.max_pair_seconds,
                                         time.perf_counter() - t0)
        stats.seconds = time.perf_counter() - t_start
        stats.temp_final = max(temps.values()) if temps else cfg.temp0
        emb_out = (np.stack(embs) if embs
                   else np.zeros((0, getattr(self.embedder, "dim", 384)),
                                 np.float32))
        return queries, responses, emb_out, stats
