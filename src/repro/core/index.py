"""MIPS indexes over the precomputed-query embeddings.

TPU adaptation of the paper's DiskANN: graph-ANN pointer-chasing is
hostile to the MXU/HBM burst model, so the index is a batched tiled MIPS
scan — a matmul, the single most roofline-friendly op on the platform —
with IVF coarse pruning for sub-linear probes and a mesh-sharded variant
(rows over "model", distributed top-k) for pod-scale stores.

  FlatIndex        — exact brute MIPS (jnp matmul + top_k; the Pallas
                     ``mips_topk`` kernel implements the same contract on
                     TPU).
  IVFIndex         — k-means coarse quantizer, scans nprobe lists; persists
                     its centroids + padded list layout (``save``/``load``)
                     so reopening a paper-scale store skips k-means.
  ShardedIndex     — rows sharded over a mesh axis, local top-k + all-gather
                     combine (repro.distributed.topk).
  IncrementalIndex — append-only max-similarity index for the OFFLINE dedup
                     loop: ``add()`` + ``max_sim()``, flat below the tier
                     boundary, IVF with assign-to-nearest-centroid appends
                     and amortized re-clustering above it.

``auto_index`` picks between the serving tiers from store size and mesh
availability (see ``select_tier`` for the exact boundaries) so callers —
the batched runtime in particular — never hard-code a tier; pass
``cache_dir=`` to load/save the IVF build product instead of re-running
k-means on every reopen.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import weakref
import zlib
from pathlib import Path
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store import quantize_rows

# Below this row count an exact flat scan is one small matmul and beats any
# pruning overhead; above it IVF's nprobe/n_lists scan fraction wins. The
# paper's 150K-pair store lands in the IVF tier.
FLAT_MAX_ROWS = 32768
# Sharding only pays once each shard is a non-trivial scan.
SHARD_MIN_ROWS = 4 * FLAT_MAX_ROWS


def _device_embs(embs) -> jnp.ndarray:
    """Host→device (N, D) float32 without a full host-side copy: a
    ``ShardedEmbeddings`` view moves one shard at a time — shipped in its
    STORED dtype (fp16 halves the transfer, int8 quarters it) and upcast /
    dequantized once on the device — so peak host memory is one shard and
    the link never carries an inflated fp32 copy."""
    if hasattr(embs, "iter_qshards"):
        parts = [jnp.asarray(np.asarray(v)).astype(jnp.float32)
                 * jnp.asarray(np.asarray(s))[:, None]
                 for v, s in embs.iter_qshards()]
    elif hasattr(embs, "iter_shards"):
        parts = [jnp.asarray(np.asarray(s)).astype(jnp.float32)
                 for s in embs.iter_shards()]
    else:
        return jnp.asarray(np.asarray(embs)).astype(jnp.float32)
    if not parts:
        return jnp.zeros(embs.shape, jnp.float32)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)


# ---------------------------------------------------------------------------
# Device-resident store cache (the serving hot path's upload-once layer)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2,))
def _flat_scan_T(q, xT, k):
    """The GEMM-layout flat scan: q (Q, D) @ xT (D, N) + top-k, one fused
    dispatch over the device-resident operand."""
    return jax.lax.top_k(q @ xT, k)


# rows gathered to the host per DeviceStore.sync step (bounds peak host
# memory during the initial upload of a paper-scale store)
_SYNC_ROWS = 65536


class DeviceStore:
    """Device-resident copy of a store's embeddings: upload once, append
    deltas, scan without ever re-shipping N×D.

    Pre-PR, every index (re)build round-tripped the full matrix through
    host fp32 (and §3.1 write-back rebuilds re-uploaded everything); this
    cache is keyed per store (``device_store_for``) and survives tier
    rebuilds, so a rebuild after write-backs ships only the new rows.

    Residency layout per backend (``layout=``):

    * ``"kernel"`` (default on TPU) — shards stay in their stored dtype:
      int8 values + per-row f32 scales for quantized stores (feeding the
      fused ``mips_topk_int8`` Pallas kernel; hot-path HBM bytes drop 4x
      vs fp32), fp16/fp32 rows otherwise (``mips_topk``).
    * ``"gemm"`` (default on CPU) — no int8 MXU exists and XLA's CPU int8
      GEMM is several times SLOWER than Eigen's fp32, so shards are
      dequantized/upcast ONCE at upload into the transposed (D, N) fp32
      layout the CPU GEMM wants (measured ~2x over the old per-(N,D)
      resident scan at N=100K, Q<=32). Disk/transfer savings and the
      quantization error are identical to the kernel layout; the
      RAM-for-speed trade is explicit.

    ``search`` is exact over whatever representation is resident. On a
    quantized store the kernel layout also quantizes the QUERY block
    (int8 x int8 -> int32 on the MXU), so its scores differ from the
    gemm layout's (f32 query x dequantized store) by the query's own
    rounding — bounded by ~query_scale * sqrt(D)/127, ~2e-3 for
    normalized 384-d embeddings; top-1 agreement on serving workloads is
    >= 0.99 either way (tests pin both).
    """

    def __init__(self, source, layout: str = "auto"):
        if layout == "auto":
            layout = "kernel" if jax.default_backend() == "tpu" else "gemm"
        if layout not in ("kernel", "gemm"):
            raise ValueError(f"unknown DeviceStore layout {layout!r}")
        self.layout = layout
        self.n_rows = 0
        self.dim: Optional[int] = None
        self.quantized = False
        self._xT = None        # gemm: (D, N) f32
        self._x = None         # kernel: (N, D) stored dtype
        self._scales = None    # kernel + quantized: (N,) f32
        self.uploads = 0       # host→device transfers (tests/benchmarks)
        # background §3.1 rebuilds sync() deltas while the serving path
        # searches the SAME cached residency — the lock keeps the
        # (_x, _scales, n_rows) triple consistent across that race
        self._sync_lock = threading.Lock()
        self.sync(source)

    @staticmethod
    def _view(source):
        return source.embeddings() if hasattr(source, "embeddings") \
            else source

    def sync(self, source) -> "DeviceStore":
        """Ingest rows the device copy doesn't have yet (the §3.1
        write-back delta); a no-op when the store hasn't grown. Safe to
        call from a background rebuild while searches are in flight."""
        with self._sync_lock:
            return self._sync_locked(source)

    def _sync_locked(self, source) -> "DeviceStore":
        view = self._view(source)
        n, d = int(view.shape[0]), int(view.shape[1])
        if self.dim is None:
            self.dim = d
        elif d != self.dim:
            raise ValueError(f"dim changed {self.dim} -> {d}")
        if n < self.n_rows:
            raise ValueError(
                f"store shrank ({self.n_rows} -> {n} rows): DeviceStore "
                "deltas are append-only — build a fresh one")
        if n == self.n_rows:
            return self
        quantized = bool(getattr(view, "is_quantized", False))
        if self.n_rows == 0:
            self.quantized = quantized
        elif quantized != self.quantized:
            raise ValueError("store changed quantization mid-flight")

        def gather(rows):
            # view.take gathers ROWS on shard views; ndarray.take would
            # gather flat elements, so plain arrays index instead
            return view.take(rows) if hasattr(view, "iter_shards") \
                else np.asarray(view[rows])

        # chunked so peak host memory is one chunk, not the whole delta
        chunks = [np.arange(lo, min(lo + _SYNC_ROWS, n))
                  for lo in range(self.n_rows, n, _SYNC_ROWS)]
        if self.layout == "gemm":
            # dequant/upcast + transpose on the host per chunk: the scan
            # operand must be PHYSICALLY (D, N) — transposing on device
            # would fold back into the slow (N, D)-contraction dot
            parts = [jnp.asarray(
                np.ascontiguousarray(gather(c).astype(np.float32).T))
                for c in chunks]
            parts = ([] if self._xT is None else [self._xT]) + parts
            self._xT = parts[0] if len(parts) == 1 \
                else jnp.concatenate(parts, axis=1)
        elif self.quantized:
            got = [view.take_q(c) for c in chunks]
            xs = ([] if self._x is None else [self._x]) \
                + [jnp.asarray(v) for v, _ in got]
            ss = ([] if self._scales is None else [self._scales]) \
                + [jnp.asarray(s) for _, s in got]
            self._x = xs[0] if len(xs) == 1 else jnp.concatenate(xs, 0)
            self._scales = ss[0] if len(ss) == 1 \
                else jnp.concatenate(ss, 0)
        else:
            xs = ([] if self._x is None else [self._x]) \
                + [jnp.asarray(gather(c)) for c in chunks]
            self._x = xs[0] if len(xs) == 1 else jnp.concatenate(xs, 0)
        self.uploads += len(chunks)
        self.n_rows = n
        return self

    def matrix(self) -> jnp.ndarray:
        """The resident rows as a device (N, D) f32 matrix (IVF fits /
        list builds reuse the residency instead of re-uploading)."""
        with self._sync_lock:
            n_rows, xT, x, scales = (self.n_rows, self._xT, self._x,
                                     self._scales)
        if n_rows == 0:
            return jnp.zeros((0, self.dim or 0), jnp.float32)
        if self.layout == "gemm":
            return xT.T
        x = x.astype(jnp.float32)
        return x * scales[:, None] if self.quantized else x

    def search(self, queries, k: int):
        """Exact flat MIPS over the resident rows: (vals, idx) ndarrays."""
        q = np.asarray(queries, np.float32)
        k = int(k)
        with self._sync_lock:      # consistent (operand, scales, n) triple
            n_rows = self.n_rows
            xT, x, scales = self._xT, self._x, self._scales
        if k > n_rows:
            raise ValueError(f"k={k} exceeds store rows N={n_rows}")
        if self.layout == "gemm":
            v, i = _flat_scan_T(jnp.asarray(q), xT, k)
        elif self.quantized:
            from repro.kernels.ops import mips_topk_int8
            q8, qs = quantize_rows(q)
            v, i = mips_topk_int8(jnp.asarray(q8), jnp.asarray(qs),
                                  x, scales, k)
        else:
            from repro.kernels.ops import mips_topk
            # the kernel scores fp16/fp32 tiles as-is (the MXU dot
            # upcasts in-register) — no per-search fp32 materialization
            v, i = mips_topk(jnp.asarray(q), x, k)
        return np.asarray(v), np.asarray(i)


# One DeviceStore per live store object: index rebuilds (write-backs, tier
# changes) get the cached residency + a delta sync instead of a re-upload.
_DEVICE_STORES: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def cached_device_store(store) -> Optional[DeviceStore]:
    """The store's cached ``DeviceStore`` if one already exists, delta-
    synced — or None, WITHOUT creating residency. IVF refits use this:
    a store that grew out of the flat tier reuses the flat residency it
    already paid for, but an IVF-scale store never pins a full flat
    device copy just to seed k-means."""
    try:
        ds = _DEVICE_STORES.get(store)
    except TypeError:
        return None
    return ds.sync(store) if ds is not None else None


def device_store_for(store, layout: str = "auto") -> DeviceStore:
    """The per-store cached ``DeviceStore`` (created on first use, delta-
    synced on every later call). Non-store sources (raw arrays, bare
    views) get a fresh uncached instance — there is no stable identity to
    key on. A cached entry is only reused when its layout matches."""
    if layout == "auto":
        layout = "kernel" if jax.default_backend() == "tpu" else "gemm"
    if not hasattr(store, "embeddings"):
        return DeviceStore(store, layout=layout)
    try:
        cached = _DEVICE_STORES.get(store)
    except TypeError:
        cached = None
    if cached is not None and cached.layout == layout:
        return cached.sync(store)
    ds = DeviceStore(store, layout=layout)
    try:
        _DEVICE_STORES[store] = ds
    except TypeError:
        pass
    return ds


class FlatIndex:
    """Exact MIPS over a device-resident copy of the embeddings
    (``DeviceStore``): the operand is shipped once in its stored dtype and
    cast/dequantized once at upload — never per query batch — and index
    rebuilds over the same store reuse the residency via
    ``device_store_for``. ``use_kernel`` forces the Pallas kernel layout
    (interpret mode on CPU); the default picks per backend."""

    def __init__(self, embs: np.ndarray = None, use_kernel: bool = False,
                 device: Optional[DeviceStore] = None):
        if device is None:
            device = DeviceStore(embs,
                                 layout="kernel" if use_kernel else "auto")
        self.dev = device
        self.use_kernel = use_kernel or device.layout == "kernel"

    def search(self, queries: np.ndarray, k: int):
        return self.dev.search(queries, k)

    def __len__(self):
        return self.dev.n_rows


# ---------------------------------------------------------------------------
# IVF (k-means coarse quantizer)
# ---------------------------------------------------------------------------


def kmeans(x: jnp.ndarray, n_clusters: int, iters: int = 10, seed: int = 0):
    """Plain Lloyd's on the device. Returns (centroids, assignment).

    ``n_clusters`` is clamped to the row count — sampling n_clusters
    distinct seed rows with ``replace=False`` is otherwise impossible (and
    used to crash on stores smaller than the requested list count)."""
    n = x.shape[0]
    n_clusters = max(1, min(int(n_clusters), int(n)))
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = x[init]

    # x is a traced ARGUMENT, not a closure capture: captured arrays are
    # baked into the jaxpr as constants, which XLA then constant-folds
    # (minutes of compile at paper-scale row counts, once per refit)
    def step(x, cent):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        a = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(a, cent.shape[0], dtype=x.dtype)
        sums = oh.T @ x
        counts = oh.sum(0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cent)
        return new, a

    step = jax.jit(step)
    for _ in range(iters):
        cent, assign = step(x, cent)
    return cent, assign


class IVFIndex:
    """IVF-Flat: coarse k-means, probe top-``nprobe`` lists, exact scan.

    Padded list layout (lists, cap, dim) so the probe scan is one gather +
    batched matmul — TPU-friendly, no ragged pointers.

    ``save``/``load`` persist the k-means product (centroids + the padded
    id layout; the vectors themselves are re-gathered from the store on
    load), so reopening a 150K-row store costs one gather instead of a
    fresh k-means fit.
    """

    def __init__(self, embs: np.ndarray, n_lists: int = 64, nprobe: int = 8,
                 seed: int = 0, device: Optional[DeviceStore] = None):
        # an ALREADY-cached DeviceStore (auto_index passes one when the
        # store grew out of the flat tier) seeds the fit from the resident
        # rows instead of re-uploading N×D; otherwise the fit matrix is a
        # transient local, released after __init__ — an IVF-scale store
        # must not pin a flat device copy. Quantized views are accepted
        # either way; centroids, fit, and padded probe lists stay fp32
        # (coarse probing is too precision-sensitive to quantize).
        x = device.matrix() if device is not None else _device_embs(embs)
        self.n_total = int(x.shape[0])
        # clamp: k-means cannot seed more lists than there are rows
        self.n_lists = max(1, min(n_lists, self.n_total))
        self.nprobe = min(nprobe, self.n_lists)
        self.loaded_from: Optional[str] = None
        cent, assign = kmeans(x, self.n_lists, seed=seed)
        self.centroids = cent
        assign = np.asarray(assign)
        cap = max(int(np.max(np.bincount(assign, minlength=self.n_lists))),
                  1)
        D = x.shape[1]
        buf = np.zeros((self.n_lists, cap, D), np.float32)
        ids = np.full((self.n_lists, cap), -1, np.int32)
        fill = np.zeros(self.n_lists, np.int32)
        xe = np.asarray(x)
        for row, a in enumerate(assign):
            buf[a, fill[a]] = xe[row]
            ids[a, fill[a]] = row
            fill[a] += 1
        self.lists = jnp.asarray(buf)
        self.ids = jnp.asarray(ids)
        self._search = jax.jit(self._search_impl, static_argnums=(1,))

    # -- persistence ----------------------------------------------------------
    @staticmethod
    def _fingerprint(lists: np.ndarray, ids: np.ndarray) -> int:
        """Content digest of a vector sample (first 256 valid rows in
        list-major order): row count alone cannot tell a rebuilt store
        with different content apart from the one the fit belongs to."""
        valid = np.flatnonzero(ids.ravel() >= 0)[:256]
        flat = lists.reshape(-1, lists.shape[-1])
        sample = np.ascontiguousarray(flat[valid], np.float32)
        return zlib.crc32(sample.tobytes())

    def save(self, path):
        """Persist centroids + padded id layout (tiny: no raw vectors —
        ``load`` re-gathers them from the store's memmap shards). Written
        atomically (tmp + rename) so a killed build never leaves a torn
        cache."""
        path = Path(path)
        meta = {"n_total": self.n_total, "n_lists": self.n_lists,
                "nprobe": self.nprobe,
                "dim": int(self.centroids.shape[1]),
                "fingerprint": self._fingerprint(np.asarray(self.lists),
                                                 np.asarray(self.ids))}
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, centroids=np.asarray(self.centroids),
                     ids=np.asarray(self.ids),
                     meta=np.frombuffer(json.dumps(meta).encode(), np.uint8))
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path, embs) -> "IVFIndex":
        """Rebuild from a ``save``d layout + the store embeddings (any
        array or ``ShardedEmbeddings`` view) — no k-means."""
        path = Path(path)
        with np.load(path) as d:
            meta = json.loads(bytes(d["meta"]).decode())
            centroids = d["centroids"]
            ids = d["ids"]
        st = cls.__new__(cls)
        st.n_total = int(meta["n_total"])
        st.n_lists = int(meta["n_lists"])
        st.nprobe = int(meta["nprobe"])
        st.loaded_from = str(path)
        st.centroids = jnp.asarray(centroids)
        valid = ids >= 0
        rows = ids[valid]
        if hasattr(embs, "iter_shards"):
            vecs = embs.take(rows)       # per-shard row gather, no full copy
        else:
            vecs = np.asarray(embs)[rows]
        buf = np.zeros(ids.shape + (int(meta["dim"]),), np.float32)
        buf[valid] = np.asarray(vecs, np.float32)
        want = meta.get("fingerprint")
        if want is not None and cls._fingerprint(buf, ids) != want:
            raise ValueError(
                f"{path}: persisted IVF fit belongs to different store "
                "content (same row count, different vectors) — rebuild")
        st.lists = jnp.asarray(buf)
        st.ids = jnp.asarray(ids)
        st._search = jax.jit(st._search_impl, static_argnums=(1,))
        return st

    def _search_impl(self, q, k):
        # 1. coarse: score centroids
        cs = q @ self.centroids.T                          # (Q, n_lists)
        _, probe = jax.lax.top_k(cs, self.nprobe)          # (Q, nprobe)
        # 2. gather probed lists and scan
        cand = self.lists[probe]                           # (Q,np,cap,D)
        cand_ids = self.ids[probe]                         # (Q,np,cap)
        s = jnp.einsum("qd,qpcd->qpc", q, cand)
        s = jnp.where(cand_ids < 0, -jnp.inf, s)
        Q = q.shape[0]
        s = s.reshape(Q, -1)
        ci = cand_ids.reshape(Q, -1)
        v, pos = jax.lax.top_k(s, k)
        return v, jnp.take_along_axis(ci, pos, axis=1)

    def search(self, queries: np.ndarray, k: int):
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = self._search(q, k)
        return np.asarray(v), np.asarray(i)

    def __len__(self):
        return self.n_total

    def reconstruct(self) -> np.ndarray:
        """The indexed rows, (N, D), rebuilt from the padded list layout
        (row order restored from the stored ids)."""
        lists = np.asarray(self.lists)
        ids = np.asarray(self.ids)
        out = np.zeros((self.n_total, lists.shape[-1]), np.float32)
        valid = ids >= 0
        out[ids[valid]] = lists[valid]
        return out

    def recall_vs_flat(self, queries, k: int = 10) -> float:
        """Mean recall@k of this IVF index against an exact flat scan over
        the same rows. 1.0 means the nprobe pruning lost nothing for these
        queries; ``auto_index`` callers use this to validate an IVF choice.

        The flat reference is built on demand from ``reconstruct()`` and
        discarded — this is a diagnostic, not a serving path, so the index
        doesn't pay a permanent 2x memory cost for it.
        """
        q = np.asarray(queries, np.float32)
        _, flat_ids = FlatIndex(self.reconstruct()).search(q, k)
        _, ivf_ids = self.search(q, k)
        hits = [len(set(f.tolist()) & set(i.tolist())) / k
                for f, i in zip(flat_ids, ivf_ids)]
        return float(np.mean(hits))


# ---------------------------------------------------------------------------
# Incremental dedup index (offline pipeline)
# ---------------------------------------------------------------------------


class IncrementalIndex:
    """Append-only max-similarity index for the offline dedup loop (§3.2 at
    paper scale): ``add(embs)`` + ``max_sim(queries)``.

    Replaces the sequential generator's quadratic scan (re-``concatenate``
    the full embedding matrix + full-matrix matmul per candidate):

    * **flat** (≤ ``flat_max_rows``): rows live in one amortized-doubling
      buffer; ``max_sim`` is a single blocked matmul per wave.
    * **ivf** (above it): rows are assigned to their nearest (max-dot)
      centroid on ``add`` and ``max_sim`` probes only the top-``nprobe``
      lists — sub-linear, approximate like any ANN dedup (the paper's
      DiskANN dedup is too). Assignment and probing use the same
      inner-product metric, so an exact duplicate always probes the list
      that holds its twin.

    Re-clustering is amortized: centroids are refit (k-means over all rows
    so far) whenever the row count crosses ``flat_max_rows * 2^k``. In the
    default deterministic mode, ``add`` splits batches exactly at those
    thresholds, so the index state is a pure function of the row sequence —
    independent of how adds were batched. That is what makes a kill-and-
    resume rebuild (re-adding shard-at-a-time) bit-identical to the
    uninterrupted build. ``background=True`` moves refits to a thread for
    throughput, giving up that determinism.
    """

    def __init__(self, dim: int, *, flat_max_rows: int = FLAT_MAX_ROWS,
                 probe_frac: float = 1 / 16, seed: int = 0,
                 background: bool = False):
        self.dim = dim
        self.flat_max_rows = flat_max_rows
        self.probe_frac = probe_frac
        self.seed = seed
        self.background = background
        self._buf = np.empty((1024, dim), np.float32)
        self._n = 0
        self._next_refit = flat_max_rows
        self.centroids: Optional[np.ndarray] = None     # (L, D) in ivf mode
        self._list_ids: List[np.ndarray] = []           # ragged int32 lists
        self._list_n: Optional[np.ndarray] = None
        self._lock = threading.Lock()
        self._refit_thread: Optional[threading.Thread] = None
        self.refits = 0

    def __len__(self) -> int:
        return self._n

    @property
    def mode(self) -> str:
        return "flat" if self.centroids is None else "ivf"

    @property
    def nprobe(self) -> int:
        """Duplicates share their twin's top-1 list by construction (same
        inner-product metric for assignment and probing), so a thin probe
        fan suffices for dedup — min 4 lists for near-boundary cases."""
        n_lists = len(self._list_ids)
        return max(1, min(n_lists,
                          max(4, int(round(n_lists * self.probe_frac)))))

    # -- append ---------------------------------------------------------------
    def add(self, embs: np.ndarray):
        embs = np.asarray(embs, np.float32)
        if embs.ndim == 1:
            embs = embs[None, :]
        if self.background:
            self._append(embs)
            if self._n >= self._next_refit and (
                    self._refit_thread is None
                    or not self._refit_thread.is_alive()):
                self._next_refit *= 2
                self._refit_thread = threading.Thread(
                    target=self._refit, daemon=True)
                self._refit_thread.start()
            return
        # deterministic mode: split the batch at refit thresholds so the
        # fit always sees exactly `threshold` rows, however adds arrive
        while len(embs):
            room = self._next_refit - self._n
            head, embs = embs[:room], embs[room:]
            self._append(head)
            if self._n == self._next_refit:
                self._refit()
                self._next_refit *= 2

    def _grow(self, need: int):
        cap = self._buf.shape[0]
        if self._n + need <= cap:
            return
        while cap < self._n + need:
            cap *= 2
        new = np.empty((cap, self.dim), np.float32)
        new[:self._n] = self._buf[:self._n]
        self._buf = new

    def _append(self, embs: np.ndarray):
        with self._lock:
            self._grow(len(embs))
            lo = self._n
            self._buf[lo:lo + len(embs)] = embs
            self._n += len(embs)
            if self.centroids is not None:
                assign = np.argmax(embs @ self.centroids.T, axis=1)
                for j, a in enumerate(assign):
                    self._list_append(int(a), lo + j)

    def _list_append(self, a: int, row: int):
        ids, n = self._list_ids[a], int(self._list_n[a])
        if n == ids.shape[0]:
            grown = np.empty(max(2 * n, 8), np.int32)
            grown[:n] = ids
            self._list_ids[a] = ids = grown
        ids[n] = row
        self._list_n[a] += 1

    def _refit(self):
        """K-means over all rows so far; rebuild the assignment lists.
        In background mode the fit runs without the lock (appends continue
        against the old centroids) and only the swap is locked."""
        with self._lock:
            n0 = self._n
            x = self._buf[:n0].copy() if self.background \
                else self._buf[:n0]
        n_lists, _ = ivf_params(n0)
        cent, assign = kmeans(jnp.asarray(x), n_lists, seed=self.seed)
        cent = np.asarray(cent)
        with self._lock:
            # re-assign by max inner product (the probe metric) so a row
            # is always found in the list its duplicates will probe first
            assign = np.argmax(self._buf[:self._n] @ cent.T, axis=1)
            self.centroids = cent
            counts = np.bincount(assign, minlength=cent.shape[0])
            self._list_ids = [np.empty(max(int(c), 8), np.int32)
                              for c in counts]
            self._list_n = np.zeros(cent.shape[0], np.int64)
            order = np.argsort(assign, kind="stable")
            sorted_assign = assign[order]
            starts = np.searchsorted(sorted_assign,
                                     np.arange(cent.shape[0]))
            ends = np.searchsorted(sorted_assign,
                                   np.arange(cent.shape[0]), side="right")
            for a in range(cent.shape[0]):
                rows = order[starts[a]:ends[a]]
                self._list_ids[a][:len(rows)] = rows
                self._list_n[a] = len(rows)
            self.refits += 1

    def drain(self):
        """Join an in-flight background refit (no-op otherwise)."""
        if self._refit_thread is not None:
            self._refit_thread.join()

    # -- query ----------------------------------------------------------------
    def max_sim(self, queries: np.ndarray) -> np.ndarray:
        """Max inner product of each query against every stored row
        (-inf when empty). Exact in flat mode; nprobe-approximate in ivf."""
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        with self._lock:
            n = self._n
            if n == 0:
                return np.full(q.shape[0], -np.inf, np.float32)
            if self.centroids is None:
                return (q @ self._buf[:n].T).max(axis=1)
            cent, buf = self.centroids, self._buf
            nprobe = self.nprobe
            Q = q.shape[0]
            cs = q @ cent.T
            probes = np.argpartition(cs, -nprobe, axis=1)[:, -nprobe:]
            # invert (query -> lists) to (list -> queries): each probed
            # list is gathered ONCE per call and scanned as one matmul
            # against every query that probes it — per-query gathers were
            # the offline-build bottleneck at paper scale
            flat = probes.ravel()
            qidx = np.repeat(np.arange(Q), nprobe)
            order = np.argsort(flat, kind="stable")
            flat, qidx = flat[order], qidx[order]
            bounds = np.searchsorted(flat, np.arange(len(self._list_ids)))
            out = np.full(Q, -np.inf, np.float32)
            for a in np.unique(flat):
                lo = bounds[a]
                hi = bounds[a + 1] if a + 1 < len(bounds) else len(flat)
                n = int(self._list_n[a])
                if n == 0:
                    continue
                qs = qidx[lo:hi]
                s = (buf[self._list_ids[a][:n]] @ q[qs].T).max(axis=0)
                np.maximum.at(out, qs, s)
            return out


class ShardedIndex:
    """Mesh-sharded exact MIPS: rows over ``shard_axis``, distributed top-k.

    Quantized views shard the int8 values + per-row scales as-is (4x less
    HBM per device; each local scan scores its int8 shard and dequantizes
    in place — see distributed/topk.py); float inputs shard fp32 exactly
    as before."""

    def __init__(self, embs: np.ndarray, mesh, shard_axis: str = "model"):
        from jax.sharding import NamedSharding, PartitionSpec as P
        n_sh = mesh.shape[shard_axis]
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.scales = None
        row_sh = NamedSharding(mesh, P(shard_axis, None))
        if getattr(embs, "is_quantized", False):
            vals, scales = embs.take_q(np.arange(embs.shape[0]))
            N, D = vals.shape
            pad = (-N) % n_sh
            if pad:       # zero rows score 0; masked out via n_real
                vals = np.concatenate(
                    [vals, np.zeros((pad, D), np.int8)], axis=0)
                scales = np.concatenate(
                    [scales, np.ones(pad, np.float32)])
            self.embs = jax.device_put(jnp.asarray(vals), row_sh)
            self.scales = jax.device_put(
                jnp.asarray(scales), NamedSharding(mesh, P(shard_axis)))
            self.n_real = N
        else:
            embs = np.asarray(embs)
            N, D = embs.shape
            pad = (-N) % n_sh
            if pad:
                embs = np.concatenate(
                    [embs, np.full((pad, D), -1e4, embs.dtype)], axis=0)
            self.n_real = N
            self.embs = jax.device_put(
                jnp.asarray(np.asarray(embs, np.float32)), row_sh)

    def search(self, queries: np.ndarray, k: int):
        from repro.distributed.topk import sharded_mips_topk
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = sharded_mips_topk(
            q, self.embs, k, mesh=self.mesh, shard_axis=self.shard_axis,
            scales=self.scales,
            n_real=self.n_real if self.scales is not None else None)
        return np.asarray(v), np.asarray(i)

    def __len__(self):
        return self.n_real


# ---------------------------------------------------------------------------
# Tier auto-selection
# ---------------------------------------------------------------------------


def select_tier(n_rows: int, mesh_axis_size: int = 1, *,
                flat_max_rows: int = FLAT_MAX_ROWS,
                shard_min_rows: int = SHARD_MIN_ROWS) -> str:
    """Pure tier decision: ``"flat" | "ivf" | "sharded"``.

    Separated from ``auto_index`` so the boundary logic is unit-testable
    without building real indexes (or a real multi-device mesh).
    """
    if n_rows <= 0:
        raise ValueError("cannot index an empty store")
    if mesh_axis_size > 1 and n_rows >= shard_min_rows:
        return "sharded"
    if n_rows <= flat_max_rows:
        return "flat"
    return "ivf"


def ivf_params(n_rows: int) -> Tuple[int, int]:
    """(n_lists, nprobe) heuristic: sqrt-N lists, probe ~1/8 of them (at
    least 8) — keeps the scanned fraction roughly constant as N grows."""
    n_lists = max(16, int(round(float(n_rows) ** 0.5)))
    nprobe = max(8, n_lists // 8)
    return n_lists, min(nprobe, n_lists)


IVF_CACHE_NAME = "index_ivf.npz"


def auto_index(store, mesh=None, *, shard_axis: str = "model",
               use_kernel: Optional[bool] = None,
               flat_max_rows: int = FLAT_MAX_ROWS,
               shard_min_rows: int = SHARD_MIN_ROWS, seed: int = 0,
               cache_dir=None):
    """Build the right index tier for ``store`` (a PrecomputedStore, or any
    object with ``.embeddings()``, or a raw (N, D) array).

    ``use_kernel=None`` routes the flat scan through the Pallas mips_topk
    kernel when running on a real TPU and keeps the plain jnp path (faster
    than interpret mode) on CPU.

    ``cache_dir`` (typically the store root) persists the IVF k-means
    product: a matching cache loads (no k-means); a stale or missing one
    rebuilds and re-saves. Flat and sharded tiers have no build product to
    cache, so the option is a no-op there.
    """
    if hasattr(store, "embeddings"):
        embs = store.embeddings()
    else:
        embs = np.asarray(store, np.float32)
    n_rows = int(embs.shape[0])
    axis_size = 1
    if mesh is not None:
        try:
            axis_size = int(mesh.shape[shard_axis])
        except (KeyError, TypeError):
            axis_size = 1
    tier = select_tier(n_rows, axis_size,
                       flat_max_rows=flat_max_rows,
                       shard_min_rows=shard_min_rows)
    is_store = hasattr(store, "embeddings")
    if tier == "sharded":
        return ShardedIndex(embs, mesh, shard_axis=shard_axis)
    if tier == "ivf":
        n_lists, nprobe = ivf_params(n_rows)
        cache = Path(cache_dir) / IVF_CACHE_NAME if cache_dir else None
        if cache is not None and cache.exists():
            try:
                idx = IVFIndex.load(cache, embs)
                if (idx.n_total == n_rows and idx.n_lists == n_lists
                        and idx.nprobe == nprobe):
                    return idx
            except Exception:
                pass              # unreadable/stale cache: rebuild below
        dev = cached_device_store(store) if is_store else None
        idx = IVFIndex(embs, n_lists=n_lists, nprobe=nprobe, seed=seed,
                       device=dev)
        if cache is not None:
            idx.save(cache)
        return idx
    layout = "auto" if use_kernel is None else \
        ("kernel" if use_kernel else "gemm")
    dev = device_store_for(store, layout=layout) if is_store \
        else DeviceStore(embs, layout=layout)
    return FlatIndex(device=dev, use_kernel=dev.layout == "kernel")
