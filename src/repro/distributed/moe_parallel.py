"""Expert-parallel MoE via shard_map + all-to-all.

Dispatch pattern (DeepSeek-class thin-expert MoE; E % ep == 0):

  1. each device routes its LOCAL tokens (router replicated, f32),
  2. scatters them into an (E, C, d) capacity buffer (sort-free, near-zero
     FLOPs — unlike GShard's one-hot einsum dispatch whose FLOPs rival the
     expert matmuls when experts are thin),
  3. all-to-all over the EP axis: (ep, E_local, C, d) -> each device now
     holds the tokens of ITS E_local experts from every peer,
  4. batched expert FFN (E_local, ep*C, d),
  5. reverse all-to-all + gather + weighted combine.

Differentiable end-to-end (all_to_all and scatters have transposes), so the
same path serves train and prefill. Capacity overflow drops tokens onto the
residual stream (standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map

from repro.models import moe as Moe
from repro.models.layers import mlp


def moe_ffn_ep(cfg, p, x, *, mesh, ep_axis="model", batch_axes=("data",)):
    """x: (B,S,d) -> (y, aux).

    Requires cfg.n_experts % ep == 0 and S % ep == 0: tokens are
    sequence-split over the EP axis (each EP peer routes a disjoint token
    shard — the DeepSeek-EP layout), so the all-to-all carries real traffic
    instead of replicated work.
    """
    E, K = cfg.n_experts, cfg.experts_per_tok
    ep = mesh.shape[ep_axis]
    assert E % ep == 0, (E, ep)
    B, S, d = x.shape
    assert S % ep == 0, (S, ep)
    E_local = E // ep
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    nb = 1
    for a in b_axes:
        nb *= mesh.shape[a]
    bspec = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    if B % max(nb, 1) != 0:
        bspec = None

    def local(x, router, experts, shared):
        Bl, Sl = x.shape[:2]
        T = Bl * Sl
        x2d = x.reshape(T, d)
        w, idx, probs = Moe.route(cfg, {"router": router}, x2d)
        slot, valid, C = Moe.dispatch_slots(cfg, idx, T)
        xk = jnp.repeat(x2d, K, axis=0)
        buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
            xk * valid[:, None].astype(x.dtype), mode="drop")
        buf = buf.reshape(ep, E_local * C, d)
        # dispatch: send chunk i to peer i (tokens for ITS experts)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        # buf: (ep, E_local*C, d) — rows grouped by (expert, src-dev capacity)
        buf = jnp.moveaxis(buf.reshape(ep, E_local, C, d), 0, 1)
        buf = buf.reshape(E_local, ep * C, d)
        out = Moe.expert_ffn(cfg, experts, buf)             # (E_local,ep*C,d)
        out = jnp.moveaxis(out.reshape(E_local, ep, C, d), 1, 0)
        out = out.reshape(ep, E_local * C, d)
        out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        out = out.reshape(E * C, d)
        yk = out.at[slot].get(mode="fill", fill_value=0)
        yk = yk * valid[:, None].astype(x.dtype)
        y = jnp.sum(yk.reshape(T, K, d) * w[..., None].astype(x.dtype),
                    axis=1)
        if cfg.n_shared_experts:
            y = y + mlp(cfg, shared, x2d)
        # load-balance aux from GLOBAL statistics: pmean the per-expert
        # mean-prob and assignment-fraction first, THEN take the product —
        # the product of local means != mean of local products.
        me = jnp.mean(probs, axis=0)                             # (E,)
        fe = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32),
                      axis=(0, 1))
        for a in b_axes + (ep_axis,):
            me = jax.lax.pmean(me, a)
            fe = jax.lax.pmean(fe, a)
        aux = E * jnp.sum(me * fe)
        return y.reshape(Bl, Sl, d), aux

    shared = p.get("shared")
    if shared is None:
        shared = {"w1": {"w": jnp.zeros((0,), x.dtype)},
                  "w2": {"w": jnp.zeros((0,), x.dtype)},
                  "w3": {"w": jnp.zeros((0,), x.dtype)}}
    sm = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, ep_axis), P(), P(ep_axis), P()),
        out_specs=(P(bspec, ep_axis), P()),
        check_vma=False)
    y, aux = sm(x, p["router"], p["experts"], shared)
    return y, aux
