"""StorInfer Runtime (§3.4, Fig 2): parallel vector search + LLM inference
with hit-cancellation.

On each query the runtime concurrently
  (a) embeds the query and searches the precomputed store (CPU/storage
      resources — a thread here; a dedicated mesh slice at pod scale), and
  (b) starts LLM inference (chunked decode on the accelerator).
If (a) returns a match with similarity >= S_th_Run, the stored response is
returned immediately and a termination signal cancels (b) at the next chunk
boundary — a miss therefore costs exactly the plain-LLM latency (the decode
ran unimpeded the whole time).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional


@dataclasses.dataclass
class QueryResult:
    response: str
    source: str               # "store" | "llm"
    hit: bool
    score: float
    matched_query: Optional[str]
    search_s: float
    llm_s: float
    latency_s: float
    chunks_run: int = 0


@dataclasses.dataclass
class RuntimeCfg:
    s_th_run: float = 0.9
    parallel: bool = True
    add_misses: bool = False   # §3.1: optionally add new pairs on miss


class StorInferRuntime:
    def __init__(self, index, store, embedder, engine=None,
                 cfg: RuntimeCfg = None):
        """index: FlatIndex/IVFIndex/ShardedIndex over store embeddings;
        store: PrecomputedStore; engine: serving.Engine or None (search-only
        mode returns misses without LLM fallback)."""
        self.index = index
        self.store = store
        self.embedder = embedder
        self.engine = engine
        self.cfg = cfg or RuntimeCfg()
        self._pool = ThreadPoolExecutor(max_workers=2)

    # -- the search half ------------------------------------------------------
    def search(self, text: str):
        t0 = time.perf_counter()
        e = self.embedder.encode([text])
        v, i = self.index.search(e, 1)
        dt = time.perf_counter() - t0
        return float(v[0, 0]), int(i[0, 0]), dt

    # -- full parallel query path ----------------------------------------------
    def query(self, text: str, *, max_new: int = 32,
              temperature=None) -> QueryResult:
        t0 = time.perf_counter()
        fut = self._pool.submit(self.search, text)

        session = None
        if self.engine is not None:
            session = self.engine.start_session(text, max_new=max_new,
                                                temperature=temperature)

        score = row = search_s = None
        while session is not None and not session.done:
            if fut.done():
                score, row, search_s = fut.result()
                if score >= self.cfg.s_th_run:
                    session.cancel()         # Fig 2 termination signal
                break                        # miss: decode continues below
            session.step_chunk()
        if score is None:                    # session won the race (or none)
            score, row, search_s = fut.result()

        if score >= self.cfg.s_th_run:
            mq, resp = self.store.get_pair(row)
            return QueryResult(
                response=resp, source="store", hit=True, score=score,
                matched_query=mq, search_s=search_s,
                llm_s=(session.decode_s + session.prefill_s) if session
                else 0.0,
                latency_s=time.perf_counter() - t0,
                chunks_run=session.chunks_run if session else 0)

        # miss: let the LLM finish (it kept decoding the whole time)
        llm_text = ""
        if session is not None:
            while not session.done:
                session.step_chunk()
            llm_text = session.text()
            if self.cfg.add_misses:
                e = self.embedder.encode([text])
                self.store.add_batch(e, [text], [llm_text])
        return QueryResult(
            response=llm_text, source="llm", hit=False, score=score,
            matched_query=None, search_s=search_s,
            llm_s=(session.decode_s + session.prefill_s) if session else 0.0,
            latency_s=time.perf_counter() - t0,
            chunks_run=session.chunks_run if session else 0)

    # -- batched search (benchmarks) --------------------------------------------
    def search_batch(self, texts, k: int = 1):
        t0 = time.perf_counter()
        e = self.embedder.encode(list(texts))
        v, i = self.index.search(e, k)
        return v, i, time.perf_counter() - t0
