"""Admission + staged serving pipeline for the batched StorInfer runtime.

Serving millions of users means queries arrive one at a time but must be
*processed* together: one embedding batch, one MIPS search batch through
the index, one LLM dispatch for the misses — the lookup cost amortized
across every in-flight request (cf. triton_distributed's queued async
engine workers). Two layers live here:

``MicroBatcher`` — the generic collect-a-microbatch-and-call-back queue
(kept as the transport-agnostic building block and the synchronous
compatibility path's admission layer):

  submit(item) -> Future        (any thread)
        |                               queue
        v
  worker thread: collect up to ``max_batch`` items, waiting at most
  ``max_wait_s`` after the first arrival, then call
  ``process_batch(items) -> results`` and resolve the futures.

``ServingPipeline`` — the stage-decoupled serving loop (§3.4, Fig 2 made
pipelined). The monolithic per-microbatch barrier (embed + search + full
batched decode + write-back, every future resolved only when the slowest
miss finished) is broken into workers connected by bounded queues:

  submit() ─▶ [admit q] ─▶ search worker (microbatched embed + MIPS)
                  │ hits (score >= S_th_Run)        │ misses
                  ▼                                 ▼
           [resolve q] ─▶ resolve worker     [decode q] ─▶ decode worker
             store.get_pair, future            persistent BatchScheduler:
             resolved the moment the           freed slots refilled from
             search returned — NEVER           newly-searched misses
             waits on any decode               between waves
                                                    │ §3.1 write-backs
                                                    ▼
                                             [writeback q] ─▶ writeback
                                               worker: store.add_batch +
                                               flush_and_rebuild off the
                                               critical path; the index
                                               is swapped atomically
                                               under the runtime's lock

Every queue is bounded (``queue_depth``), so a slow stage exerts
backpressure on its producer instead of buffering unboundedly —
``submit`` itself blocks once the admit queue is full.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import CancelledError, Future, InvalidStateError
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Submission:
    """One queued query, its per-request generation knobs, and the
    per-stage stamps the pipeline fills in as it flows through (the
    per-submission timing the latency percentiles are computed from)."""
    text: str
    max_new: int = 32
    temperature: Optional[float] = None
    future: Future = dataclasses.field(default_factory=Future)
    # pipeline routing + timing (stamped by the stages)
    t_admit: float = 0.0      # perf_counter at submit()
    t_search: float = 0.0     # search stage resolved the score
    t_routed: float = 0.0     # enqueued to the next stage
    hit: bool = False
    score: float = 0.0
    row: int = -1
    embedding: Optional[np.ndarray] = None   # threaded to write-back


@dataclasses.dataclass
class BatcherStats:
    batches: int = 0
    items: int = 0
    max_batch_seen: int = 0

    @property
    def mean_batch(self) -> float:
        return self.items / self.batches if self.batches else 0.0


class MicroBatcher:
    """Drains a submission queue into microbatches on a worker thread.

    ``process_batch`` receives a list of ``Submission`` and must return one
    result per submission (same order). Exceptions fail every future in
    the batch — the callers see the error, the worker keeps serving.
    """

    def __init__(self, process_batch: Callable[[List[Submission]],
                                               Sequence[Any]],
                 *, max_batch: int = 32, max_wait_s: float = 0.005):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._process = process_batch
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.stats = BatcherStats()
        self._q: "queue.Queue[Optional[Submission]]" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._stopping = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "MicroBatcher":
        if self._worker is None or not self._worker.is_alive():
            self._stopping = False
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="microbatcher")
            self._worker.start()
        return self

    def stop(self, drain: bool = True):
        """Stop the worker. ``drain=True`` processes what is already
        queued first; otherwise pending futures are cancelled. Either
        way ``_stopping`` is raised FIRST, so a concurrent ``submit``
        cannot slip a submission in behind the shutdown sentinel (where
        its future would hang unresolved forever)."""
        if self._worker is None:
            return
        self._stopping = True
        if not drain:
            try:
                while True:
                    sub = self._q.get_nowait()
                    if sub is not None:
                        sub.future.cancel()
            except queue.Empty:
                pass
        self._q.put(None)                      # wake + shutdown sentinel
        self._worker.join(timeout=30)
        self._worker = None

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # -- producer side ------------------------------------------------------
    def submit(self, text: str, *, max_new: int = 32) -> Future:
        if (self._stopping or self._worker is None
                or not self._worker.is_alive()):
            raise RuntimeError("MicroBatcher is not running; call start()")
        sub = Submission(text=text, max_new=max_new)
        self._q.put(sub)
        # re-check AFTER the put: a concurrent stop() may have slipped its
        # sentinel in between the check above and our enqueue, leaving
        # this submission behind it where no worker would ever resolve
        # it. cancel() failing means the worker raced us and took it —
        # then the future resolves normally and the submit stands.
        if self._stopping and sub.future.cancel():
            raise RuntimeError("MicroBatcher is not running; call start()")
        return sub.future

    # -- worker side --------------------------------------------------------
    def _collect(self) -> Optional[List[Submission]]:
        """Block for the first item, then batch what arrives within the
        wait window. Returns None on the shutdown sentinel."""
        first = self._q.get()
        if first is None:
            return None
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            try:
                nxt = (self._q.get_nowait() if remaining <= 0
                       else self._q.get(timeout=remaining))
            except queue.Empty:
                break
            if nxt is None:                     # re-queue sentinel and stop
                self._q.put(None)
                break
            batch.append(nxt)
        return batch

    def _run(self):
        while True:
            batch = self._collect()
            if batch is None:
                return
            # atomically move futures to RUNNING; a False return means the
            # caller cancelled first (and cancel() can no longer succeed
            # afterwards, so set_result below cannot race)
            batch = [s for s in batch
                     if s.future.set_running_or_notify_cancel()]
            if not batch:
                continue
            try:
                results = self._process(batch)
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"process_batch returned {len(results)} results "
                        f"for {len(batch)} submissions")
            except Exception as e:              # noqa: BLE001
                for s in batch:
                    s.future.set_exception(e)
                continue
            self.stats.batches += 1
            self.stats.items += len(batch)
            self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                            len(batch))
            for s, r in zip(batch, results):
                s.future.set_result(r)


# ---------------------------------------------------------------------------
# Stage-decoupled serving pipeline
# ---------------------------------------------------------------------------


def _pct_ms(lat_s) -> Optional[dict]:
    """p50/p99/mean (ms) over a latency window; None when empty."""
    if not lat_s:
        return None
    a = np.asarray(lat_s, np.float64) * 1e3
    return {"n": int(a.size), "p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


@dataclasses.dataclass
class StageStats:
    """Per-stage accounting: items through the stage, cumulative time
    those items spent queued BEFORE it (the stage's admission wait), and
    the deepest its input queue got (backpressure indicator)."""
    items: int = 0
    wait_s: float = 0.0
    max_depth: int = 0

    @property
    def mean_wait_ms(self) -> float:
        return self.wait_s / self.items * 1e3 if self.items else 0.0


class PipelineStats:
    """Thread-safe pipeline accounting: per-stage queue depth + wait, and
    rolling hit/miss end-to-end latency windows for percentiles."""

    def __init__(self, window: int = 4096):
        self.stages: Dict[str, StageStats] = {
            "search": StageStats(), "resolve": StageStats(),
            "decode": StageStats(), "writeback": StageStats()}
        self.hit_lat = collections.deque(maxlen=window)
        self.miss_lat = collections.deque(maxlen=window)
        self.search_batches = 0
        self.writeback_errors = 0
        self._lock = threading.Lock()

    def record_wait(self, stage: str, wait_s: float, depth: int, n: int = 1):
        with self._lock:
            st = self.stages[stage]
            st.items += n
            st.wait_s += wait_s
            st.max_depth = max(st.max_depth, depth)

    def record_latency(self, hit: bool, latency_s: float):
        with self._lock:
            (self.hit_lat if hit else self.miss_lat).append(latency_s)

    def snapshot(self, depths: Optional[Dict[str, int]] = None) -> dict:
        """Plain-dict view (the ``SystemStats.pipeline`` payload)."""
        with self._lock:
            return {
                "stages": {
                    name: {"items": st.items,
                           "mean_wait_ms": st.mean_wait_ms,
                           "max_depth": st.max_depth,
                           "depth": (depths or {}).get(name, 0)}
                    for name, st in self.stages.items()},
                "hit": _pct_ms(self.hit_lat),
                "miss": _pct_ms(self.miss_lat),
                "search_batches": self.search_batches,
                "writeback_errors": self.writeback_errors,
            }


class ServingPipeline:
    """The stage-decoupled serving loop over a ``BatchedRuntime`` (see the
    module docstring for the stage diagram).

    Contracts:

    * a HIT future resolves the moment its microbatch's MIPS search
      returns — it never waits on any decode;
    * misses flow into ONE persistent continuous-batching
      ``BatchScheduler``: freed decode slots (finished or cancelled) are
      refilled from newly-searched misses between waves, never a full
      batch teardown per admission;
    * §3.1 write-back and ``flush_and_rebuild`` run on a background
      worker (``async_writeback``), the rebuilt index swapped atomically
      under the runtime's index lock — in-flight searches keep the old
      snapshot, later ones see the new;
    * every queue is bounded: a saturated stage blocks its producer
      (``submit`` included) instead of buffering without limit.

    ``stop(drain=True)`` flows a sentinel through every stage in order,
    so nothing already admitted is dropped; ``drain=False`` cancels
    queued + in-flight futures (``CancelledError``) and tears down fast.
    """

    def __init__(self, runtime, *, max_batch: int = 32,
                 max_wait_s: float = 0.005, queue_depth: int = 64,
                 decode_slots: int = 4, async_writeback: bool = True):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if decode_slots < 1:
            raise ValueError("decode_slots must be >= 1")
        self.rt = runtime
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.decode_slots = decode_slots
        self.async_writeback = async_writeback
        self.stats = PipelineStats()
        self._admit_q: "queue.Queue[Optional[Submission]]" = \
            queue.Queue(maxsize=queue_depth)
        self._resolve_q: "queue.Queue[Optional[Submission]]" = \
            queue.Queue(maxsize=queue_depth)
        self._decode_q: "queue.Queue[Optional[Submission]]" = \
            queue.Queue(maxsize=queue_depth)
        self._wb_q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self.scheduler = None            # the decode worker's BatchScheduler
        self._threads: List[threading.Thread] = []
        self._stopping = False
        self._abort = False
        self._admit_done = False         # search worker saw the sentinel
        self._lifecycle = threading.Lock()

    @property
    def _has_decode(self) -> bool:
        return self.rt.engine is not None

    @property
    def _wants_writeback(self) -> bool:
        return self._has_decode and self.rt.cfg.add_misses

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ServingPipeline":
        with self._lifecycle:
            if self._threads:
                return self
            self._stopping = self._abort = self._admit_done = False
            workers = [("pipeline-search", self._search_worker),
                       ("pipeline-resolve", self._resolve_worker)]
            if self._has_decode:
                workers.append(("pipeline-decode", self._decode_worker))
            if self._wants_writeback and self.async_writeback:
                workers.append(("pipeline-writeback",
                                self._writeback_worker))
            self._threads = [threading.Thread(target=fn, daemon=True,
                                              name=name)
                             for name, fn in workers]
            for t in self._threads:
                t.start()
            return self

    def stop(self, drain: bool = True):
        """Stop every stage. ``drain=True`` finishes everything already
        admitted first (sentinels flow admit → search → resolve/decode →
        write-back); ``drain=False`` cancels pending + in-flight work."""
        with self._lifecycle:
            if not self._threads:
                return
            self._stopping = True
            if not drain:
                self._abort = True
            self._admit_q.put(None)
            for t in self._threads:
                t.join(timeout=60)
            # anything that slipped into a queue behind the sentinels
            for q_ in (self._admit_q, self._resolve_q, self._decode_q):
                try:
                    while True:
                        s = q_.get_nowait()
                        if s is not None:
                            _cancel_future(s.future)
                except queue.Empty:
                    pass
            self._threads = []

    def __enter__(self) -> "ServingPipeline":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # -- producer side ------------------------------------------------------
    def submit(self, text: str, *, max_new: int = 32,
               temperature: Optional[float] = None) -> Future:
        """Enqueue one query (blocks when the admit queue is full — the
        pipeline's backpressure reaches the caller). The future resolves
        to a ``QueryResult``: at search time for hits, at decode
        completion for misses."""
        if self._stopping or not self._alive():
            raise RuntimeError("ServingPipeline is not running; "
                               "call start()")
        sub = Submission(text=text, max_new=max_new,
                         temperature=temperature)
        sub.t_admit = time.perf_counter()
        # backpressure put that cannot strand the caller: while the
        # pipeline runs this blocks like a plain put, but a producer
        # parked on a FULL queue whose workers have stopped (no consumer
        # left, cleanup drain already past) must wake up and bail
        while True:
            try:
                self._admit_q.put(sub, timeout=0.05)
                break
            except queue.Full:
                if self._stopping:
                    raise RuntimeError("ServingPipeline is not running; "
                                       "call start()") from None
        # re-check AFTER the put: stop() may have raced us between the
        # aliveness check and the enqueue, and a submission landing after
        # its drain would hang forever. cancel() failing means a worker
        # took it first — then it resolves normally.
        if self._stopping and sub.future.cancel():
            raise RuntimeError("ServingPipeline is not running; "
                               "call start()")
        return sub.future

    def _alive(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    def queue_depths(self) -> Dict[str, int]:
        return {"search": self._admit_q.qsize(),
                "resolve": self._resolve_q.qsize(),
                "decode": self._decode_q.qsize(),
                "writeback": self._wb_q.qsize()}

    def stats_snapshot(self) -> dict:
        snap = self.stats.snapshot(self.queue_depths())
        sched = self.scheduler
        if sched is not None:
            snap["decode_slots"] = {"slots": sched.B, "waves": sched.waves,
                                    "admitted": sched.admitted,
                                    "slot_uses": list(sched.slot_uses)}
        return snap

    # -- stage 2: embed + MIPS search (microbatched) ------------------------
    def _collect(self) -> List[Submission]:
        """Block for the first item, microbatch the rest of the wait
        window. A consumed shutdown sentinel sets ``_admit_done`` instead
        of being re-queued — re-putting into the BOUNDED admit queue
        could block forever against producers refilling the freed slots
        (this worker is the queue's only consumer)."""
        first = self._admit_q.get()
        if first is None:
            self._admit_done = True
            return []
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            try:
                nxt = (self._admit_q.get_nowait() if remaining <= 0
                       else self._admit_q.get(timeout=remaining))
            except queue.Empty:
                break
            if nxt is None:
                self._admit_done = True
                break
            batch.append(nxt)
        return batch

    def _search_worker(self):
        while not self._admit_done:
            batch = self._collect()
            if not batch:
                continue
            batch = [s for s in batch
                     if s.future.set_running_or_notify_cancel()]
            if not batch:
                continue
            if self._abort:
                for s in batch:
                    _cancel_future(s.future)
                continue
            now = time.perf_counter()
            self.stats.record_wait(
                "search", sum(now - s.t_admit for s in batch),
                self._admit_q.qsize() + len(batch), n=len(batch))
            try:
                scores, rows, embs, _ = self.rt._search_batch(
                    [s.text for s in batch])
            except Exception as e:              # noqa: BLE001
                for s in batch:
                    _set_future_exception(s.future, e)
                continue
            t = time.perf_counter()
            embs = np.asarray(embs)
            with self.rt._stats_lock:
                self.rt.stats.batches += 1
            with self.stats._lock:
                self.stats.search_batches += 1
            s_th = self.rt.cfg.s_th_run
            for qi, s in enumerate(batch):
                s.t_search = t
                s.score = float(scores[qi])
                s.row = int(rows[qi])
                s.hit = s.score >= s_th
                s.t_routed = time.perf_counter()
                if s.hit or not self._has_decode:
                    self._resolve_q.put(s)       # stage 3: hit-resolve
                else:
                    s.embedding = embs[qi]       # threaded to write-back
                    self._decode_q.put(s)        # stage 4: decode
        # shutdown: propagate the sentinel downstream
        self._resolve_q.put(None)
        if self._has_decode:
            self._decode_q.put(None)

    # -- stage 3: hit-resolve (and engine-less miss resolve) ----------------
    def _resolve_worker(self):
        from repro.core.runtime import QueryResult
        while True:
            s = self._resolve_q.get()
            if s is None:
                break
            if self._abort:
                _cancel_future(s.future)
                continue
            now = time.perf_counter()
            self.stats.record_wait("resolve", now - s.t_routed,
                                   self._resolve_q.qsize() + 1)
            try:
                if s.hit:
                    mq, resp = self.rt.store.get_pair(s.row)
                else:                   # miss with no engine behind it
                    mq, resp = None, ""
                done = time.perf_counter()
                qr = QueryResult(
                    response=resp, source="store" if s.hit else "llm",
                    hit=s.hit, score=s.score, matched_query=mq,
                    search_s=s.t_search - s.t_admit, llm_s=0.0,
                    latency_s=done - s.t_admit)
                self._account(qr)
                s.future.set_result(qr)
            except Exception as e:              # noqa: BLE001
                _set_future_exception(s.future, e)

    # -- stage 4: continuous-batching decode --------------------------------
    def _decode_worker(self):
        pending: Dict[int, Submission] = {}
        try:
            self._decode_loop(pending)
        except Exception as e:              # noqa: BLE001 — engine died:
            # fail everything in flight, then keep consuming (failing new
            # arrivals) until the shutdown sentinel so no future hangs
            for s in pending.values():
                _set_future_exception(s.future, e)
            pending.clear()
            while True:
                s = self._decode_q.get()
                if s is None:
                    break
                _set_future_exception(s.future, e)
        if self._wants_writeback and self.async_writeback:
            self._wb_q.put(None)

    def _decode_loop(self, pending: Dict[int, "Submission"]):
        from repro.serving.engine import BatchScheduler, Request
        sched = BatchScheduler(self.rt.engine,
                               batch_size=self.decode_slots)
        self.scheduler = sched
        next_rid = 0
        sentinel = False

        def admit(s: Submission):
            nonlocal next_rid
            now = time.perf_counter()
            self.stats.record_wait("decode", now - s.t_routed,
                                   self._decode_q.qsize() + 1)
            req = Request(rid=next_rid, prompt=s.text, max_new=s.max_new,
                          temperature=s.temperature)
            pending[next_rid] = s
            next_rid += 1
            sched.submit(req)

        while True:
            if not pending:
                if sentinel:
                    break
                s = self._decode_q.get()     # idle: block for work
                if s is None:
                    break
                if self._abort:
                    _cancel_future(s.future)
                    continue
                admit(s)
            if not sentinel:
                # refill: everything already searched joins the slot pool
                # now, so freed slots are reused between waves
                try:
                    while True:
                        s = self._decode_q.get_nowait()
                        if s is None:
                            sentinel = True
                            break
                        if self._abort:
                            _cancel_future(s.future)
                        else:
                            admit(s)
                except queue.Empty:
                    pass
            if self._abort:
                for s in pending.values():
                    _cancel_future(s.future)
                pending.clear()
                continue
            if pending:
                sched.step_chunk()           # admit into free slots + decode
                for r in sched.drain_finished():
                    self._finish_miss(pending.pop(r.rid), r)

    def _finish_miss(self, s: Submission, req):
        from repro.core.runtime import QueryResult
        now = time.perf_counter()
        text = self.rt.engine.tok.decode(req.out_ids) if req.out_ids else ""
        qr = QueryResult(
            response=text, source="llm", hit=False, score=s.score,
            matched_query=None, search_s=s.t_search - s.t_admit,
            llm_s=now - s.t_search, latency_s=now - s.t_admit,
            chunks_run=req.chunks, cancelled=req.cancelled)
        self._account(qr)
        try:
            s.future.set_result(qr)
        except InvalidStateError:
            pass
        if self._wants_writeback and text:
            if self.async_writeback:         # stage 5: off the critical path
                self._wb_q.put((time.perf_counter(), s.embedding, s.text,
                                text))
            else:
                self.rt._writeback(np.asarray([s.embedding]), [s.text],
                                   [text])

    # -- stage 5: async write-back + background rebuild ---------------------
    def _writeback_worker(self):
        while True:
            item = self._wb_q.get()
            if item is None:
                break
            items = [item]
            done = False
            try:
                while True:                  # batch whatever is queued
                    nxt = self._wb_q.get_nowait()
                    if nxt is None:
                        done = True
                        break
                    items.append(nxt)
            except queue.Empty:
                pass
            if not self._abort:
                # wait = how long each pair actually sat queued (a slow
                # flush_and_rebuild shows up here, the stage's real
                # backpressure signal)
                now = time.perf_counter()
                self.stats.record_wait(
                    "writeback", sum(now - t for t, _, _, _ in items),
                    self._wb_q.qsize() + len(items), n=len(items))
                try:
                    self.rt._writeback(
                        np.stack([e for _, e, _, _ in items]),
                        [q for _, _, q, _ in items],
                        [r for _, _, _, r in items])
                except Exception:            # noqa: BLE001
                    with self.stats._lock:
                        self.stats.writeback_errors += len(items)
            if done:
                break

    def _account(self, qr):
        with self.rt._stats_lock:
            st = self.rt.stats
            st.queries += 1
            st.hits += int(qr.hit)
            st.misses += int(not qr.hit)
        self.stats.record_latency(qr.hit, qr.latency_s)


def _cancel_future(f: Future):
    """Cancel a pending future, or fail a running one with
    CancelledError — either way result() stops blocking."""
    if not f.cancel():
        _set_future_exception(f, CancelledError())


def _set_future_exception(f: Future, e: BaseException):
    try:
        f.set_exception(e)
    except InvalidStateError:
        pass
