"""Batched StorInfer serving throughput: sequential one-query-at-a-time
(`StorInfer.query`, the paper's Fig-2 loop) vs the batched path
(`StorInfer.query_batch`) on the SAME system — one facade, one shared
auto-tiered index.

Amortization is the whole story: one embedding batch + one MIPS dispatch
per microbatch instead of per query. Emits a BENCH_batched_serve.json
point with queries/sec, p50/p99 latency, and the batched/sequential
speedup (acceptance floor: >= 4x at batch 32).

  PYTHONPATH=src python benchmarks/bench_batched_serve.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parents[1]
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np

from benchmarks.common import out_write
from repro.api import StorInfer, SystemCfg, make_embedder, tier_of
from repro.core.runtime import BatchedRuntimeCfg
from repro.core.store import PrecomputedStore


def build_synth_store(root, emb, n_rows: int, batch: int = 2048):
    """Write synthetic query/response pairs to ``root`` and close the
    store (reopen via ``StorInfer.open``); embeddings come from the real
    embedder so sequential and batched paths search identical data."""
    store = PrecomputedStore(root, dim=emb.dim)
    for lo in range(0, n_rows, batch):
        hi = min(lo + batch, n_rows)
        qs = [f"synthetic question {i} about topic {i % 97} and "
              f"entity {i % 31}" for i in range(lo, hi)]
        rs = [f"stored answer number {i}." for i in range(lo, hi)]
        store.add_batch(emb.encode(qs), qs, rs)
    store.close()


def user_queries(n: int, n_store: int, hit_frac: float = 0.5, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for j in range(n):
        if rng.random() < hit_frac:
            i = int(rng.integers(0, n_store))
            out.append(f"synthetic question {i} about topic {i % 97} and "
                       f"entity {i % 31}")
        else:
            out.append(f"novel unseen query {j} zebra {rng.integers(1e6)}")
    return out


def pcts(lat_s):
    a = np.asarray(lat_s)
    return {"p50_ms": float(np.percentile(a, 50) * 1e3),
            "p99_ms": float(np.percentile(a, 99) * 1e3),
            "mean_ms": float(a.mean() * 1e3)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small store/query count for CI")
    ap.add_argument("--n-store", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args(argv)

    n_store = args.n_store or (2000 if args.smoke else 20000)
    n_q = args.n_queries or (128 if args.smoke else 512)
    B = args.batch

    with tempfile.TemporaryDirectory() as td:
        build_synth_store(td, make_embedder("hash"), n_store)
        cfg = SystemCfg(s_th_run=0.9,
                        batched=BatchedRuntimeCfg(max_batch=B))
        with StorInfer.open(td, cfg) as si:
            tier = tier_of(si.index)
            queries = user_queries(n_q, n_store)

            # warm the jit caches on both paths before timing
            si.query(queries[0])
            si.query_batch(queries[:B])

            # -- sequential: the paper's one-at-a-time race loop -----------
            seq_lat = []
            t0 = time.perf_counter()
            seq_hits = 0
            for q in queries:
                t1 = time.perf_counter()
                r = si.query(q)
                seq_lat.append(time.perf_counter() - t1)
                seq_hits += int(r.hit)
            seq_total = time.perf_counter() - t0
            seq_qps = n_q / seq_total

            # -- batched: microbatches of B through one index dispatch -----
            bat_lat = []
            t0 = time.perf_counter()
            bat_hits = 0
            for lo in range(0, n_q, B):
                chunk = queries[lo:lo + B]
                t1 = time.perf_counter()
                rs = si.query_batch(chunk)
                dt = time.perf_counter() - t1
                bat_lat.extend([dt] * len(chunk))  # each waits its batch
                bat_hits += sum(r.hit for r in rs)
            bat_total = time.perf_counter() - t0
            bat_qps = n_q / bat_total

        assert seq_hits == bat_hits, (seq_hits, bat_hits)
        speedup = bat_qps / seq_qps
        payload = {
            "n_store": n_store, "n_queries": n_q, "batch": B,
            "index_tier": tier, "hit_rate": seq_hits / n_q,
            "sequential": {"qps": seq_qps, **pcts(seq_lat)},
            "batched": {"qps": bat_qps, **pcts(bat_lat)},
            "speedup_qps": speedup,
            "smoke": bool(args.smoke),
        }
        out_write("BENCH_batched_serve", payload)
        print(f"store={n_store} ({tier})  queries={n_q}  batch={B}")
        print(f"sequential: {seq_qps:8.1f} q/s  "
              f"p50={payload['sequential']['p50_ms']:.2f}ms "
              f"p99={payload['sequential']['p99_ms']:.2f}ms")
        print(f"batched:    {bat_qps:8.1f} q/s  "
              f"p50={payload['batched']['p50_ms']:.2f}ms "
              f"p99={payload['batched']['p99_ms']:.2f}ms")
        print(f"speedup: {speedup:.1f}x (floor 4x)")
        if speedup < 4.0:
            print("WARNING: batched speedup below the 4x acceptance floor",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
