"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain 512 placeholder CPU devices.

Mesh topology (TPU v5e target):
  single pod : (data=16, model=16)              = 256 chips
  multi-pod  : (pod=2, data=16, model=16)       = 512 chips

Axis roles:
  pod   — outermost data parallelism (pure gradient all-reduce; crosses DCI)
  data  — FSDP / batch sharding within a pod
  model — tensor parallel (heads/ffn/vocab), expert parallel (MoE),
          KV-sequence parallel (flash-decoding), index-row parallel (MIPS)
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(model: int = 1, data: int = 1):
    """Mesh over whatever devices exist locally (CPU tests)."""
    n = len(jax.devices())
    assert model * data <= n, (model, data, n)
    devs = np.asarray(jax.devices()[: model * data]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    """Axes a global batch dim shards over (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes_of(mesh):
        n *= mesh.shape[a]
    return n
