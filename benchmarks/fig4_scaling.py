"""Fig 4: hit rate & storage vs number of precomputed queries (SQuAD),
deduplicated vs random generation.

One generation run per mode; hit rates at size N are computed over the
first-N accepted pairs (exactly the store you would have had stopping at
N). Storage bytes from the store's on-disk accounting (index + metadata
split — the paper's 810 MB + 20 MB at 150K pairs).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import N_STORE, build_setup, hit_stats, out_write

S_TH_RUN = 0.9


def main():
    sizes = [n for n in (500, 1000, 2000, 4000, 8000, 16000, 32000)
             if n <= N_STORE] or [N_STORE]
    rows = []
    for dedup in (False, True):
        setup = build_setup("squad", dedup)
        per_row_bytes = (setup["store"].storage_bytes()["total_bytes"]
                         / max(setup["store"].count, 1))
        for n in sizes:
            hr, _, _, search_s = hit_stats(setup, S_TH_RUN, n_prefix=n)
            rows.append({"mode": "dedup" if dedup else "random",
                         "n_queries": n, "hit_rate": hr,
                         "storage_mb": n * per_row_bytes / 1e6,
                         "search_s": search_s})
    payload = {"s_th_run": S_TH_RUN, "rows": rows,
               "paper_point": {"n": 150000, "storage_mb": 830,
                               "hit_rate": 0.225}}
    out_write("fig4_scaling", payload)
    print("name,mode,n_queries,hit_rate,storage_mb")
    for r in rows:
        print(f"fig4,{r['mode']},{r['n_queries']},{r['hit_rate']:.3f},"
              f"{r['storage_mb']:.2f}")
    # monotone coverage growth + dedup dominance at the largest size
    for mode in ("random", "dedup"):
        hrs = [r["hit_rate"] for r in rows if r["mode"] == mode]
        assert hrs[-1] >= hrs[0], (mode, hrs)
    hr_at = {(r["mode"], r["n_queries"]): r["hit_rate"] for r in rows}
    assert hr_at[("dedup", sizes[-1])] >= hr_at[("random", sizes[-1])]
    return payload


if __name__ == "__main__":
    main()
