"""End-to-end training driver: train a small LM on the KB corpus with the
full substrate — sharded AdamW, grad accumulation, async checkpointing,
restart, and (optional) int8 gradient compression.

  PYTHONPATH=src python examples/train_small.py --steps 200
  PYTHONPATH=src python examples/train_small.py --steps 200 --resume
  PYTHONPATH=src python examples/train_small.py --arch mamba2-130m --full

Default config is a ~20M-param llama-style model so a few hundred steps run
in CPU-minutes; --full uses the real 130M mamba2 (the "~100M model" spec
point) at ~30 s/step on CPU.
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.kb import build_kb
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.training import checkpoint as CK
from repro.training import compression as GC
from repro.training import data as D
from repro.training import optimizer as O
from repro.training import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL assigned config (mamba2-130m fits)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    kb = build_kb("squad", n_docs=60)
    texts = [d.text() for d in kb.docs]
    tok = Tokenizer.from_texts(texts, max_vocab=4096)

    base = get_config(args.arch)
    cfg = base if args.full else dataclasses.replace(
        reduced(base), d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
        n_layers=8)
    cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size)
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.1f}M "
          f"steps={args.steps}")

    run = M.RunCfg(attn_impl="naive", remat=False, scan_layers=True)
    ocfg = O.AdamWCfg(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    compress = None
    err_key = None
    if args.compress:
        def compress(grads, opt_state):
            dq, err = GC.compress_grads(grads, opt_state["grad_err"])
            opt_state = dict(opt_state, grad_err=err)
            return dq, opt_state

    step_fn = jax.jit(T.make_train_step(cfg, run, ocfg, accum=args.accum,
                                        compress=compress))
    data = D.TextFileData(texts, tok, args.batch, args.seq)
    ck = CK.Checkpointer(args.ckpt)

    start = 0
    if args.resume and ck.latest_step() is not None:
        state, meta = ck.restore()
        params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        opt = jax.tree_util.tree_map(jnp.asarray, state["opt"])
        start = meta["step"]
        print(f"resumed from step {start}")
    else:
        params = M.init_model(jax.random.PRNGKey(0), cfg,
                              dtype=jnp.float32)
        opt = O.init(params)
        if args.compress:
            opt["grad_err"] = GC.init_error_state(params)

    t0 = time.time()
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step_fn(params, opt, b)
        if (i + 1) % 20 == 0 or i == start:
            print(f"step {i + 1:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i - start + 1):.2f}s/step)")
        if (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt})
    ck.save(args.steps, {"params": params, "opt": opt}, blocking=True)
    print(f"done; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
