"""Serving engine + StorInfer runtime: chunked decode correctness,
cancellation semantics, continuous batching, parallel hit/miss paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.embedder import HashEmbedder
from repro.core.index import FlatIndex
from repro.core.kb import build_kb
from repro.core.runtime import RuntimeCfg, StorInferRuntime
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.serving.engine import BatchScheduler, Engine, Request


@pytest.fixture(scope="module")
def tiny_engine():
    kb = build_kb("squad", n_docs=4)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-1.7b")),
        vocab_size=tok.vocab_size, n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    run = M.RunCfg(attn_impl="naive", remat=False)
    return Engine(cfg, params, tok, run, max_len=96, chunk=4), kb, tok


def test_session_greedy_deterministic(tiny_engine):
    eng, kb, tok = tiny_engine
    t1 = eng.generate("hello world what is", max_new=8)
    t2 = eng.generate("hello world what is", max_new=8)
    assert t1 == t2


def test_session_cancellation_stops_decode(tiny_engine):
    eng, kb, tok = tiny_engine
    s = eng.start_session("tell me something", max_new=64)
    s.step_chunk()
    chunks_before = s.chunks_run
    s.cancel()
    s.step_chunk()  # no-op after cancel
    assert s.done and s.chunks_run == chunks_before


def test_chunked_decode_matches_forward(tiny_engine):
    """Greedy chunked decode == argmax over full-forward logits stepwise."""
    eng, kb, tok = tiny_engine
    prompt = "the height of"
    got = eng.generate(prompt, max_new=6)
    # manual reference decode using forward() each step
    ids = tok.encode(prompt, bos=True)
    cfg, params = eng.cfg, eng.params
    run = eng.run
    for _ in range(6):
        logits, _ = M.forward(cfg, params,
                              {"tokens": jnp.asarray([ids], jnp.int32)}, run)
        ids.append(int(jnp.argmax(logits[0, -1])))
    want = tok.decode(ids[len(tok.encode(prompt, bos=True)):])
    assert got == want


def test_batch_scheduler_runs_and_cancels(tiny_engine):
    eng, kb, tok = tiny_engine
    sched = BatchScheduler(eng, batch_size=2)
    reqs = [Request(rid=i, prompt=f"question number {i}", max_new=6)
            for i in range(4)]
    for r in reqs:
        sched.submit(r)
    sched.cancel(2)  # cancelled while waiting
    done = sched.run_to_completion()
    assert len(done) == 4
    by_id = {r.rid: r for r in done}
    assert by_id[2].cancelled and len(by_id[2].out_ids) == 0
    for rid in (0, 1, 3):
        assert len(by_id[rid].out_ids) > 0


def test_runtime_hit_returns_stored_and_cancels(tiny_engine, tmp_path):
    eng, kb, tok = tiny_engine
    emb = HashEmbedder()
    store = PrecomputedStore(tmp_path / "s", dim=384)
    qs = ["what is the height of aurora bridge?",
          "who founded the meridian institute?"]
    rs = ["the height is two hundred meters.", "elena marchetti founded it."]
    store.add_batch(emb.encode(qs), qs, rs)
    store.flush()
    rt = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                          engine=eng, cfg=RuntimeCfg(s_th_run=0.9))
    # exact query -> hit with stored response
    res = rt.query(qs[0], max_new=64)
    assert res.hit and res.source == "store"
    assert res.response == rs[0]
    # near-paraphrase -> hit at a lower runtime threshold (Table 2 regime)
    rt_lo = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                             engine=eng, cfg=RuntimeCfg(s_th_run=0.6))
    res2 = rt_lo.query("what's the height of aurora bridge?", max_new=64)
    assert res2.hit
    # unrelated -> miss falls through to LLM (gibberish text, but source=llm)
    res3 = rt.query("completely unrelated zebra xylophone", max_new=8)
    assert not res3.hit and res3.source == "llm"
    assert res3.chunks_run >= 1


def test_runtime_search_only_mode(tiny_engine, tmp_path):
    eng, kb, tok = tiny_engine
    emb = HashEmbedder()
    store = PrecomputedStore(tmp_path / "s2", dim=384)
    store.add_batch(emb.encode(["hello there"]), ["hello there"], ["hi."])
    store.flush()
    rt = StorInferRuntime(FlatIndex(store.embeddings()), store, emb,
                          engine=None)
    r = rt.query("hello there")
    assert r.hit and r.response == "hi."
    r2 = rt.query("zebra xylophone unrelated")
    assert not r2.hit and r2.response == ""
