"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container), so
the same call sites run the kernel body on CPU for correctness and compile
to Mosaic on a real TPU (interpret=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (combine_splits,
                                            decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mips_topk import mips_topk_pallas
from repro.kernels.mips_topk_int8 import mips_topk_int8_pallas


def _default_interpret():
    return jax.default_backend() != "tpu"


def _combine_tiles(vals, idx, k):
    """Reduce per-tile candidates (nt, Q, k) to the global top-k. Tiles are
    flattened in (tile, rank) order, which is (value desc, index asc)
    within a tile and index-asc across tiles — so lax.top_k's
    first-occurrence tie-break preserves the kernels' (value desc, index
    asc) contract end to end."""
    nt, Q = vals.shape[0], vals.shape[1]
    vflat = jnp.moveaxis(vals, 0, 1).reshape(Q, nt * k)
    iflat = jnp.moveaxis(idx, 0, 1).reshape(Q, nt * k)
    v, pos = jax.lax.top_k(vflat, k)
    return v, jnp.take_along_axis(iflat, pos, axis=1)


@functools.partial(jax.jit, static_argnums=(2, 3))
def mips_topk(q, x, k, tile_n=512):
    """q: (Q,D); x: (N,D) -> exact (vals (Q,k), GLOBAL idx (Q,k)).

    ``tile_n`` is clamped to the (128-aligned) store size so small stores —
    common early in a serving run, before write-backs grow them — don't
    scan a mostly-padded tile; the per-tile top-k needs k <= tile_n.
    """
    n = x.shape[0]
    if k > n:
        raise ValueError(f"k={k} exceeds store rows N={n}")
    tile_n = max(min(tile_n, -(-n // 128) * 128), k)
    vals, idx = mips_topk_pallas(q, x, k, tile_n=tile_n,
                                 interpret=_default_interpret())
    return _combine_tiles(vals, idx, k)


@functools.partial(jax.jit, static_argnums=(4, 5))
def mips_topk_int8(q, q_scale, x, x_scale, k, tile_n=512):
    """Quantized exact-over-the-quantized-grid MIPS: q (Q,D) int8 with
    per-row f32 ``q_scale`` (Q,), x (N,D) int8 with per-row ``x_scale``
    (N,) -> (dequantized vals (Q,k), GLOBAL idx (Q,k)). Same clamping and
    combine as ``mips_topk``; bit-for-bit against ref.mips_topk_int8_ref.
    """
    n = x.shape[0]
    if k > n:
        raise ValueError(f"k={k} exceeds store rows N={n}")
    tile_n = max(min(tile_n, -(-n // 128) * 128), k)
    vals, idx = mips_topk_int8_pallas(q, q_scale, x, x_scale, k,
                                      tile_n=tile_n,
                                      interpret=_default_interpret())
    return _combine_tiles(vals, idx, k)


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, q_block=256, kv_block=512):
    """Model layout: q (B,S,Hq,D); k,v (B,T,Hkv,D) -> (B,S,Hq,D)."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention_pallas(qt, kt, vt, causal=causal, q_block=q_block,
                               kv_block=kv_block,
                               interpret=_default_interpret())
    return jnp.transpose(o, (0, 2, 1, 3))


@functools.partial(jax.jit, static_argnums=(4,))
def decode_attention(q, k, v, lengths, n_splits=8):
    """q: (B,Hq,D); k,v: (B,T,Hkv,D); lengths (B,) -> (B,Hq,D)."""
    o, m, l = decode_attention_pallas(q, k, v, lengths, n_splits=n_splits,
                                      interpret=_default_interpret())
    return combine_splits(o, m, l)
