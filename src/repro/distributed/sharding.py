"""Logical-axis sharding rules -> NamedSharding pytrees.

One rule engine for params / optimizer state / caches / batches. Rules are
(path-regex -> per-dim logical axes); logical axes resolve to mesh axes only
when the dim size divides the shard count (else that dim replicates) — this
is what lets e.g. grok's 8 experts fall back from expert-parallel to
TP-on-d_ff, or mamba2's odd in_proj width replicate, without per-arch
special cases.

Logical axes:
  TP    -> "model"
  FSDP  -> ("pod", "data") (as available / divisible)
  BATCH -> ("pod", "data")
  SEQ   -> "model"   (KV-sequence parallel for decode caches)
  EP    -> "model"   (expert parallel)
  REP   -> replicated
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

TP, FSDP, BATCH, SEQ, EP, REP = "TP", "FSDP", "BATCH", "SEQ", "EP", "REP"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-compat shard_map: newer jax exposes ``jax.shard_map`` with
    ``check_vma``; older releases only have the experimental one with
    ``check_rep``. All in-repo call sites go through this wrapper."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def _resolve(logical: str, dim: int, mesh) -> Optional[object]:
    """Map a logical axis to mesh axes, honoring divisibility."""
    names = mesh.axis_names
    if logical == REP:
        return None
    if logical in (TP, SEQ, EP):
        if "model" in names and dim % mesh.shape["model"] == 0:
            return "model"
        return None
    if logical in (FSDP, BATCH):
        axes = [a for a in ("pod", "data") if a in names]
        # prefer the full product, then drop axes from the left
        while axes:
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if dim % n == 0:
                return tuple(axes) if len(axes) > 1 else axes[0]
            axes = axes[1:]
        return None
    raise ValueError(logical)


def spec_for(shape, logical_axes, mesh) -> P:
    assert len(shape) >= len(logical_axes), (shape, logical_axes)
    # right-align the rule (leading stack dims replicate)
    pad = len(shape) - len(logical_axes)
    axes = [REP] * pad + list(logical_axes)
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        r = _resolve(ax, dim, mesh)
        # a mesh axis may appear only once in a PartitionSpec
        flat = r if isinstance(r, tuple) else (r,) if r else ()
        if any(f in used for f in flat):
            r = None
        for f in flat:
            used.add(f)
        out.append(r)
    return P(*out)


# ---------------------------------------------------------------------------
# Param rules (matched against "/"-joined pytree path, first match wins)
#
# Attention projections are HEAD-AWARE: the flat (d, H*hd) out-dim may only
# TP-shard when the head count divides the model axis — otherwise the
# (B,S,H,hd) reshape cuts across shard boundaries and GSPMD re-gathers the
# activations every layer (measured: ~4.3 GB/layer of f32 all-gathers on
# llama3.2-3b whose 24 heads don't divide 16). Non-divisible q-heads =>
# replicate the projection (redundant compute over "model", zero resharding
# — what Megatron does when TP > heads); divisible q-heads with
# non-divisible kv-heads => Megatron-GQA kv replication (+ repeat_kv in the
# attention kernel).
# ---------------------------------------------------------------------------

PARAM_RULES = [
    (r"embed/w$", [TP, FSDP]),
    (r"lm_head/w$", [FSDP, TP]),
    (r"lm_head/b$", [TP]),
    # attention projections (head-awareness patched in param_specs)
    (r"(attn|xattn)/wq/w$", [FSDP, TP]),
    (r"(attn|xattn)/w[kv]/w$", [FSDP, TP]),
    (r"(attn|xattn)/wq/b$", [TP]),
    (r"(attn|xattn)/w[kv]/b$", [TP]),
    (r"(attn|xattn)/wo/w$", [TP, FSDP]),
    (r"(attn|xattn)/wo/b$", [REP]),
    # MLA
    (r"attn/wdkv/w$", [FSDP, REP]),
    (r"attn/wuk$", [REP, TP, REP]),
    (r"attn/wuv$", [REP, TP, REP]),
    # MoE experts: EP on the expert dim; FSDP the d_model dim. When E does
    # not divide the model axis (grok: 8 experts on 16-way model), EP
    # resolves to None and the d_ff dim TP-shards instead via the next rule
    # component (handled by divisibility in spec_for).
    (r"moe/experts/w[13]$", [EP, FSDP, TP]),
    (r"moe/experts/w2$", [EP, TP, FSDP]),
    (r"moe/router/w$", [REP, REP]),
    (r"moe/shared/w[13]/w$", [FSDP, TP]),
    (r"moe/shared/w2/w$", [TP, FSDP]),
    # dense MLPs
    (r"mlp/w[13]/w$", [FSDP, TP]),
    (r"mlp/w2/w$", [TP, FSDP]),
    # SSM
    (r"ssm/in_proj/w$", [FSDP, TP]),
    (r"ssm/out_proj/w$", [TP, FSDP]),
    (r"ssm/conv_w$", [REP, TP]),
    (r"ssm/conv_b$", [TP]),
    (r"ssm/(A_log|dt_bias|D)$", [REP]),
    # norms and anything else small
    (r".*", [REP]),
]

# EP constraint: when experts ARE expert-parallel (E % model == 0) the
# d_ff dim must stay unsharded for the all-to-all path; spec_for's
# used-axis bookkeeping enforces that automatically ("model" appears once).


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def heads_shardable(cfg, mesh):
    """(q_heads_ok, kv_heads_ok) on this mesh's model axis."""
    if "model" not in mesh.axis_names:
        return False, False
    n = mesh.shape["model"]
    q_ok = cfg.n_heads > 0 and cfg.n_heads % n == 0
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % n == 0
    if cfg.use_mla:  # MLA: per-head expansion weights (r,H,*) shard on H
        kv_ok = q_ok
    return q_ok, kv_ok


def param_specs(params_or_struct, mesh, cfg=None):
    """PartitionSpec pytree for a param pytree (works on ShapeDtypeStructs).

    ``cfg`` enables head-aware attention sharding (see PARAM_RULES note).
    """
    q_ok, kv_ok = heads_shardable(cfg, mesh) if cfg is not None else (True,
                                                                      True)

    def one(path, leaf):
        p = _path_str(path)
        for pat, rule in PARAM_RULES:
            if re.search(pat, p):
                rule = list(rule)
                if re.search(r"(attn|xattn)/wq/", p) and not q_ok:
                    rule = [FSDP, REP] if p.endswith("/w") else [REP]
                elif re.search(r"(attn|xattn)/w[kv]/", p) and not kv_ok:
                    rule = [FSDP, REP] if p.endswith("/w") else [REP]
                elif re.search(r"(attn|xattn)/wo/w$", p) and not q_ok:
                    rule = [REP, FSDP]
                elif re.search(r"attn/wu[kv]$", p) and not q_ok:
                    rule = [REP, REP, REP]
                return spec_for(leaf.shape, rule, mesh)
        raise AssertionError(p)

    return jax.tree_util.tree_map_with_path(one, params_or_struct)


def param_shardings(params_or_struct, mesh, cfg=None):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params_or_struct, mesh, cfg))


# ---------------------------------------------------------------------------
# Cache rules (decode KV / SSM state). Leading dim is the layer stack.
# ---------------------------------------------------------------------------

CACHE_RULES = [
    (r"(^|/)(k|v|ak|av)$", [REP, BATCH, SEQ, REP, REP]),   # (L,B,M,Hkv,hd)
    (r"(^|/)(ckv|krope)$", [REP, BATCH, SEQ, REP]),        # (L,B,M,r)
    (r"(^|/)x[kv]$", [REP, BATCH, REP, REP, REP]),         # (L,B,Tenc,H,hd)
    (r"(^|/)h$", [REP, BATCH, TP, REP, REP]),              # (L,B,H,P,N)
    (r"(^|/)conv$", [REP, BATCH, REP, TP]),                # (L,B,W-1,C)
    (r".*", [REP]),
]


def cache_specs(cache_struct, mesh):
    def one(path, leaf):
        p = _path_str(path)
        for pat, rule in CACHE_RULES:
            if re.search(pat, p):
                return spec_for(leaf.shape, rule, mesh)
        raise AssertionError(p)

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def cache_shardings(cache_struct, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  cache_specs(cache_struct, mesh))


# ---------------------------------------------------------------------------
# Batch rules
# ---------------------------------------------------------------------------

BATCH_RULES = [
    (r"mrope_positions$", [REP, BATCH, REP]),              # (3,B,S)
    (r"frames$", [BATCH, REP, REP]),                       # (B,Tenc,d)
    (r".*", [BATCH, REP]),                                 # tokens/labels/pos
]


def batch_specs(batch_struct, mesh):
    def one(path, leaf):
        p = _path_str(path)
        for pat, rule in BATCH_RULES:
            if re.search(pat, p):
                return spec_for(leaf.shape, rule, mesh)
        raise AssertionError(p)

    return jax.tree_util.tree_map_with_path(one, batch_struct)


def batch_shardings(batch_struct, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  batch_specs(batch_struct, mesh))


def replicated(mesh):
    return NamedSharding(mesh, P())
