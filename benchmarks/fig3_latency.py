"""Fig 3: vector-search latency vs LLM-inference latency per dataset.

Measured: flat-MIPS search over a paper-scale 150K x 384 store (real wall
clock, this host) and the tiny-JAX-LM engine. Modeled: the paper's H100 +
LLaMA-8B operating point and the TPU v5e target via core.latency (prefill
compute-bound + decode memory-bound). The paper reports ~0.02 s search flat
across datasets vs 0.1-0.5 s LLM inference (8.6x average speedup; 3.5x vs
decode alone) — the table printed here reproduces those ratios from the
model and our measured search point.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import DATASETS, out_write
from repro.core import latency as L
from repro.api import make_index
from repro.core.kb import PROFILES

N_PARAMS_8B = 8.0e9
OUT_TOKENS = 64
# effective context tokens per dataset (knowledge chunk + scaffold + query)
CTX = {"squad": 400, "narrativeqa": 1200, "triviaqa": 3000}


def measured_search_latency(n=150_000, d=384, q=1, repeat=10):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    idx = make_index("flat", x)
    qs = x[:q] + 0.01
    idx.search(qs, 10)  # warmup/compile
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        idx.search(qs, 10)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    search_s = measured_search_latency()
    rows = []
    for ds in DATASETS:
        h100 = L.llm_latency(L.H100, N_PARAMS_8B, CTX[ds], OUT_TOKENS)
        v5e = L.llm_latency(L.V5E, N_PARAMS_8B, CTX[ds], OUT_TOKENS)
        rows.append({
            "dataset": ds, "ctx_tokens": CTX[ds],
            "search_s_measured_150k": search_s,
            "llm_h100_total_s": h100["total_s"],
            "llm_h100_decode_s": h100["decode_s"],
            "llm_v5e_total_s": v5e["total_s"],
            "speedup_vs_llm": h100["total_s"] / search_s,
            "speedup_vs_decode_only": h100["decode_s"] / search_s,
        })
    avg_speedup = float(np.mean([r["speedup_vs_llm"] for r in rows]))
    avg_vs_decode = float(np.mean([r["speedup_vs_decode_only"]
                                   for r in rows]))
    payload = {"rows": rows, "avg_speedup": avg_speedup,
               "avg_speedup_vs_decode": avg_vs_decode,
               "paper_claim": {"search_s": 0.02, "avg_speedup": 8.6,
                               "vs_decode": 3.5}}
    out_write("fig3_latency", payload)
    print("name,dataset,search_s,llm_total_s,llm_decode_s,speedup")
    for r in rows:
        print(f"fig3,{r['dataset']},{r['search_s_measured_150k']:.5f},"
              f"{r['llm_h100_total_s']:.4f},{r['llm_h100_decode_s']:.4f},"
              f"{r['speedup_vs_llm']:.2f}")
    print(f"fig3_summary,avg_speedup={avg_speedup:.2f},"
          f"avg_vs_decode={avg_vs_decode:.2f},paper=8.6/3.5")
    return payload


if __name__ == "__main__":
    main()
