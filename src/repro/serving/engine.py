"""Serving engine: prefill + CHUNKED decode with inter-chunk cancellation.

The paper's Fig-2 "termination signal" cannot preempt a launched XLA
program, so decode runs as jit'd chunks of K tokens (one dispatch each);
between chunks the host checks cancellation (StorInfer's vector-search hit)
and the session stops paying for further compute within <= one chunk.
The same structure gives continuous batching its insertion points.

Components:
  Engine          — jit'd prefill / decode-chunk programs for one config
  Session         — single-request chunked generation with .cancel()
  BatchScheduler  — fixed-slot continuous batching over a shared cache;
                    per-slot cancellation == StorInfer hit-cancellation
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tokenizer import EOS
from repro.models import model as M


def sample_token(logits, rng, temperature):
    lg = logits.astype(jnp.float32)
    if temperature is None:
        return jnp.argmax(lg, axis=-1)
    t = jnp.maximum(temperature, 1e-6)
    return jax.random.categorical(rng, lg / t, axis=-1)


class Engine:
    """One model, jit'd once; serves many sessions."""

    def __init__(self, cfg, params, tokenizer, run: M.RunCfg = None,
                 max_len: int = 256, chunk: int = 8):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.run = run or M.RunCfg(attn_impl="naive", remat=False)
        self.max_len = max_len
        self.chunk = chunk
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_chunk = jax.jit(self._decode_chunk_impl)
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=(0,))

    # -- jit bodies -----------------------------------------------------------
    def _prefill_impl(self, params, tokens):
        batch = {"tokens": tokens}
        logits, cache = M.prefill(self.cfg, params, batch, self.run,
                                  max_len=self.max_len)
        return logits, cache

    def _decode_chunk_impl(self, params, token, cache, cache_len, rng,
                           temperature, live):
        """Runs ``chunk`` decode steps. live: (B,) bool — dead slots decode
        but their cache writes are masked out (slot freed semantics)."""

        def body(carry, _):
            tok, cache, clen, rng = carry
            rng, sub = jax.random.split(rng)
            logits, new_cache = M.decode_step(self.cfg, params, tok, cache,
                                              clen, self.run)
            nxt = sample_token(logits[:, -1, :], sub, temperature)[:, None]
            nxt = nxt.astype(jnp.int32)
            keep = live[:, None]
            nxt = jnp.where(keep, nxt, tok)
            new_cache = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    jnp.reshape(live, (1, -1) + (1,) * (n.ndim - 2)), n, o),
                new_cache, cache)
            return (nxt, new_cache, clen + 1, rng), nxt[:, 0]

        (tok, cache, clen, _), toks = jax.lax.scan(
            body, (token, cache, cache_len, rng), None, length=self.chunk)
        return tok, cache, clen, jnp.transpose(toks)  # (B, chunk)

    def _write_slot_impl(self, batch_cache, one_cache, slot):
        """Insert a prefilled single-request cache at batch slot ``slot``."""

        def wr(bc, oc):
            return jax.lax.dynamic_update_slice(
                bc, oc.astype(bc.dtype),
                (0, slot) + (0,) * (bc.ndim - 2))

        return jax.tree_util.tree_map(wr, batch_cache, one_cache)

    # -- single-shot generation ------------------------------------------------
    def generate(self, prompt: str, max_new: int = 32, temperature=None,
                 seed: int = 0) -> str:
        s = self.start_session(prompt, max_new=max_new,
                               temperature=temperature, seed=seed)
        while not s.done:
            s.step_chunk()
        return s.text()


    def start_session(self, prompt: str, max_new: int = 32, temperature=None,
                      seed: int = 0) -> "Session":
        return Session(self, prompt, max_new, temperature, seed)

    # -- batch session API ----------------------------------------------------
    def start_batch_session(self, prompts, *, max_new=32, temperature=None,
                            batch_size: int = None) -> "BatchSession":
        return BatchSession(self, prompts, max_new=max_new,
                            temperature=temperature, batch_size=batch_size)

    def generate_batch(self, prompts, *, max_new=32, temperature=None,
                       batch_size: int = None) -> List[str]:
        s = self.start_batch_session(prompts, max_new=max_new,
                                     temperature=temperature,
                                     batch_size=batch_size)
        s.run()
        return [s.text(i) for i in range(s.n)]


class Session:
    """Single-request chunked generation with host-side cancellation."""

    def __init__(self, engine: Engine, prompt: str, max_new, temperature,
                 seed):
        self.e = engine
        ids = engine.tok.encode(prompt, bos=True)[: engine.max_len - 1]
        tokens = jnp.asarray([ids], jnp.int32)
        t0 = time.perf_counter()
        logits, cache = engine._prefill(engine.params, tokens)
        self.prefill_s = time.perf_counter() - t0
        self.cache = cache
        self.cache_len = jnp.asarray(len(ids) - 1, jnp.int32)
        self.token = jnp.asarray(
            [[int(jnp.argmax(logits[0, -1]))]], jnp.int32)
        self.out_ids: List[int] = [int(self.token[0, 0])]
        self.max_new = max_new
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.cancelled = False
        self.decode_s = 0.0
        self.chunks_run = 0

    @property
    def done(self) -> bool:
        return (self.cancelled or len(self.out_ids) >= self.max_new
                or (self.out_ids and self.out_ids[-1] == EOS))

    def cancel(self):
        """The paper's termination signal (takes effect between chunks)."""
        self.cancelled = True

    def step_chunk(self):
        if self.done:
            return
        t0 = time.perf_counter()
        self.rng, sub = jax.random.split(self.rng)
        live = jnp.ones((1,), bool)
        self.token, self.cache, self.cache_len, toks = \
            self.e._decode_chunk(self.e.params, self.token, self.cache,
                                 self.cache_len + 1, sub,
                                 self.temperature, live)
        self.cache_len = self.cache_len - 1
        toks = np.asarray(toks[0])
        for t in toks:
            if len(self.out_ids) >= self.max_new or t == EOS:
                break
            self.out_ids.append(int(t))
        self.decode_s += time.perf_counter() - t0
        self.chunks_run += 1

    def text(self) -> str:
        return self.e.tok.decode(self.out_ids)


# ---------------------------------------------------------------------------
# Continuous batching with per-slot (hit-)cancellation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    rid: int
    prompt: str
    max_new: int = 32
    temperature: Optional[float] = None
    out_ids: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    slot: int = -1
    t_done: float = 0.0       # perf_counter stamp when the slot retired
    chunks: int = 0           # decode chunks this request was live for


class BatchScheduler:
    """Fixed B slots over one shared batched cache; requests enter in
    equal-prompt-length waves (prefill -> slot write), leave on
    EOS/max/cancel. Cancellation is the StorInfer hit path: the slot is
    freed at the next chunk boundary.

    Admission is wave-gated: the cache keeps a single shared ``cache_len``,
    so a new prompt may only be admitted when no slot is mid-decode (a
    mid-flight admission would reset ``cache_len`` under the live slots)
    and every prompt admitted into one wave must tokenize to the same
    length. Mixed-length traffic simply forms multiple waves.

    The scheduler is built to be PERSISTENT: slots join and leave between
    waves (a freed slot — finished or hit-cancelled — is refilled from
    ``waiting`` as soon as the wave drains) rather than the whole batch
    being torn down per admission. ``ServingPipeline``'s decode stage
    keeps one instance alive across every microbatch and feeds misses in
    continuously; ``waves`` / ``admitted`` / ``slot_uses`` account for
    the reuse."""

    def __init__(self, engine: Engine, batch_size: int = 4):
        self.e = engine
        self.B = batch_size
        cfg = engine.cfg
        self.cache = M.init_cache(cfg, batch_size, engine.max_len)
        self.token = jnp.zeros((batch_size, 1), jnp.int32)
        self.live = np.zeros(batch_size, bool)
        self.reqs: List[Optional[Request]] = [None] * batch_size
        self.cache_len = jnp.asarray(0, jnp.int32)
        self.waiting: List[Request] = []
        self.finished: List[Request] = []
        self.rng = jax.random.PRNGKey(0)
        self.waves = 0                      # admission waves opened
        self.admitted = 0                   # requests given a slot, ever
        self.slot_uses = [0] * batch_size   # admissions per slot (reuse)

    def submit(self, req: Request):
        self.waiting.append(req)

    @property
    def idle(self) -> bool:
        """Nothing decoding and nothing waiting for a slot."""
        return not self.live.any() and not self.waiting

    def drain_finished(self) -> List[Request]:
        """Pop and return everything finished since the last drain (the
        persistent-loop accessor; ``BatchSession.results`` reads the
        accumulating ``finished`` list instead)."""
        done, self.finished = self.finished, []
        return done

    def cancel(self, rid: int):
        for r in self.reqs:
            if r is not None and r.rid == rid:
                r.cancelled = True
        for r in self.waiting:
            if r.rid == rid:
                r.cancelled = True

    def _admit(self):
        if self.live.any():
            return          # wave in flight; next wave starts once it drains
        wave_len = None
        wave_temp = _UNSET = object()
        free = list(range(self.B))
        while free and self.waiting:
            req = self.waiting[0]
            if req.cancelled:
                self.waiting.pop(0)
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                continue
            ids = self.e.tok.encode(req.prompt, bos=True)
            ids = ids[: self.e.max_len - req.max_new - 1]
            if wave_len is not None and len(ids) != wave_len:
                break       # different prompt length -> opens the next wave
            if wave_temp is not _UNSET and req.temperature != wave_temp:
                break       # decode runs ONE temperature per chunk, so a
            #                 wave admits only same-temperature requests
            #                 (mixed traffic forms waves, like lengths)
            self.waiting.pop(0)
            wave_temp = req.temperature
            if wave_len is None:
                self.waves += 1
            wave_len = len(ids)
            slot = free.pop(0)
            self.admitted += 1
            self.slot_uses[slot] += 1
            tokens = jnp.asarray([ids], jnp.int32)
            logits, one_cache = self.e._prefill(self.e.params, tokens)
            self.cache = self.e._write_slot(self.cache, one_cache,
                                            jnp.asarray(slot, jnp.int32))
            first = int(jnp.argmax(logits[0, -1]))
            req.out_ids.append(first)
            req.slot = slot
            self.token = self.token.at[slot, 0].set(first)
            self.live[slot] = True
            self.reqs[slot] = req
            self.cache_len = jnp.asarray(wave_len - 1, jnp.int32)

    def _retire(self):
        for slot in range(self.B):
            r = self.reqs[slot]
            if r is None:
                continue
            if (r.cancelled or len(r.out_ids) >= r.max_new
                    or (r.out_ids and r.out_ids[-1] == EOS)):
                r.done = True
                r.t_done = time.perf_counter()
                self.finished.append(r)
                self.reqs[slot] = None
                self.live[slot] = False

    def step_chunk(self):
        self._admit()
        self._retire()
        if not self.live.any():
            return False
        self.rng, sub = jax.random.split(self.rng)
        temps = [r.temperature for r in self.reqs if r is not None]
        temp = temps[0] if temps and temps[0] is not None else None
        self.token, self.cache, self.cache_len, toks = self.e._decode_chunk(
            self.e.params, self.token, self.cache, self.cache_len + 1, sub,
            temp, jnp.asarray(self.live))
        self.cache_len = self.cache_len - 1
        toks = np.asarray(toks)
        for slot in range(self.B):
            r = self.reqs[slot]
            if r is None:
                continue
            r.chunks += 1
            for t in toks[slot]:
                if len(r.out_ids) >= r.max_new or t == EOS:
                    break
                r.out_ids.append(int(t))
        self._retire()
        return True

    def run_to_completion(self, max_chunks=1000):
        for _ in range(max_chunks):
            self._admit()
            if not self.step_chunk() and not self.waiting:
                break
        return self.finished


# ---------------------------------------------------------------------------
# Batch session API (used by core.runtime.BatchedRuntime)
# ---------------------------------------------------------------------------


class BatchSession:
    """A batch of prompts decoded together with per-request cancellation —
    the batched analogue of ``Session``. ``cancel(i)`` is the StorInfer
    termination signal for prompt ``i``; it takes effect at the next chunk
    boundary (or before prefill if the request is still waiting)."""

    def __init__(self, engine: Engine, prompts: Sequence[str], *,
                 max_new=32, temperature=None, batch_size: int = None):
        self.n = len(prompts)
        slots = min(self.n, batch_size) if batch_size else self.n
        self.sched = BatchScheduler(engine, batch_size=max(slots, 1))
        per_req_max = (list(max_new) if isinstance(max_new, (list, tuple))
                       else [max_new] * self.n)
        self.reqs = [Request(rid=i, prompt=p, max_new=per_req_max[i],
                             temperature=temperature)
                     for i, p in enumerate(prompts)]
        for r in self.reqs:
            self.sched.submit(r)
        self.decode_s = 0.0
        self.chunks_run = 0

    @property
    def done(self) -> bool:
        return len(self.sched.finished) >= self.n

    def cancel(self, i: int):
        self.sched.cancel(i)

    def step_chunk(self):
        if self.done:
            return
        t0 = time.perf_counter()
        self.sched._admit()
        if self.sched.step_chunk():
            self.chunks_run += 1
        self.decode_s += time.perf_counter() - t0

    def run(self, max_chunks: int = 10000) -> List[Request]:
        for _ in range(max_chunks):
            if self.done:
                break
            self.step_chunk()
        return self.results()

    def results(self) -> List[Request]:
        return sorted(self.sched.finished, key=lambda r: r.rid)

    def text(self, i: int) -> str:
        return self.sched.e.tok.decode(self.reqs[i].out_ids)


