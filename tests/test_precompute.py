"""Offline precompute pipeline: wave-mode generator semantics vs the
sequential reference, checkpoint/resume byte-identity, the incremental
dedup index, IVF persistence + n_lists clamping, and the store's lazy
multi-shard embedding view."""
import json

import numpy as np
import pytest

import repro.core.index as RI
from repro.core.embedder import HashEmbedder
from repro.core.generator import (GenCfg, QueryGenerator, SyntheticOracleLM,
                                  chunk_key)
from repro.core.index import (FlatIndex, IncrementalIndex, IVFIndex,
                              auto_index)
from repro.core.kb import build_kb
from repro.core.precompute import (BuildKilled, PrecomputeCfg,
                                   PrecomputePipeline, STATE_KEY)
from repro.core.store import PrecomputedStore, ShardedEmbeddings
from repro.core.tokenizer import Tokenizer


@pytest.fixture(scope="module")
def kb_env():
    kb = build_kb("squad", n_docs=6)
    emb = HashEmbedder()
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    return kb, emb, tok, chunks


def mkpipe(kb, emb, tok, wave, **cfg_kw):
    return PrecomputePipeline(SyntheticOracleLM(kb), emb, tok,
                              GenCfg(dedup=True),
                              PrecomputeCfg(wave=wave, **cfg_kw))


# ---------------------------------------------------------------------------
# Wave-mode generator semantics
# ---------------------------------------------------------------------------


def test_wave1_matches_sequential_reference(kb_env):
    """At wave=1 the pipeline consumes the RNG in the same order and makes
    the same accept/discard/temperature decisions as the sequential
    generator — bitwise-identical output on a fixed seed."""
    kb, emb, tok, chunks = kb_env
    gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True))
    sq, sr, se, ss = gen.generate(chunks, 120, seed=3)
    bq, br, be, bs = mkpipe(kb, emb, tok, wave=1).run(chunks, 120, seed=3)
    assert sq == bq
    assert sr == br
    np.testing.assert_array_equal(se, be)
    assert (ss.generated, ss.discarded) == (bs.generated, bs.discarded)
    assert ss.temp_final == bs.temp_final


def test_wave_mode_dedup_and_temperature_invariants(kb_env):
    """Batched waves preserve §3.2 semantics: no accepted pair reaches
    S_th_Gen (including wave-internal collisions), collisions bump the
    per-chunk temperature, and the temperature respects its cap."""
    kb, emb, tok, chunks = kb_env
    q, r, e, stats = mkpipe(kb, emb, tok, wave=16).run(chunks, 150, seed=0)
    assert len(q) == len(r) == len(e) == 150
    sims = e @ e.T - np.eye(len(e))
    assert sims.max() < 0.99, "accepted pair above S_th_Gen"
    assert stats.discarded > 0, "dedup never triggered (test too easy)"
    assert 0.7 < stats.temp_final <= 1.0 + 1e-9


def test_wave_mode_random_baseline(kb_env):
    kb, emb, tok, chunks = kb_env
    pipe = PrecomputePipeline(SyntheticOracleLM(kb), emb, tok,
                              GenCfg(dedup=False), PrecomputeCfg(wave=16))
    q, _, e, stats = pipe.run(chunks, 150, seed=0)
    assert stats.discarded == 0
    sims = e @ e.T - np.eye(len(e))
    assert sims.max() >= 0.99, "random generation produced no duplicates?"


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------


def test_kill_and_resume_store_is_byte_identical(kb_env, tmp_path):
    kb, emb, tok, chunks = kb_env
    A, B = tmp_path / "uninterrupted", tmp_path / "resumed"

    sa = PrecomputedStore(A, dim=emb.dim, shard_rows=64)
    mkpipe(kb, emb, tok, wave=8, checkpoint_every=3).run(
        chunks, 160, store=sa, seed=7)
    sa.close()

    sb = PrecomputedStore(B, dim=emb.dim, shard_rows=64)
    with pytest.raises(BuildKilled):
        mkpipe(kb, emb, tok, wave=8, checkpoint_every=3).run(
            chunks, 160, store=sb, seed=7, _kill_after_waves=7)
    sb._text_f.close()     # the kill: buffers reach disk, memory state dies

    sb2 = PrecomputedStore.open_(B)
    _, _, _, stats = mkpipe(kb, emb, tok, wave=8, checkpoint_every=3).run(
        chunks, 160, store=sb2, seed=7)
    sb2.close()
    assert 0 < stats.resumed_rows < 160
    assert stats.resumed_rows + stats.generated == 160

    for f in ["text.jsonl", "offsets.npy"] + sorted(
            p.name for p in A.glob("emb_*.npy")):
        assert (A / f).read_bytes() == (B / f).read_bytes(), f
    ma = json.loads((A / "manifest.json").read_text())
    mb = json.loads((B / "manifest.json").read_text())
    assert ma["count"] == mb["count"] == 160
    assert ma["shards"] == mb["shards"]
    # checkpoint-heavy flushing must not fragment: layout is a pure
    # function of the row count (full shards + at most one tail)
    assert len(ma["shards"]) == -(-160 // 64)
    sa_state = {k: v for k, v in ma["extra"][STATE_KEY].items()
                if k != "elapsed"}
    sb_state = {k: v for k, v in mb["extra"][STATE_KEY].items()
                if k != "elapsed"}
    assert sa_state == sb_state    # incl. the RNG bit-generator state


def test_resume_refuses_different_chunk_contents(kb_env, tmp_path):
    """Same chunk COUNT, different world (another KB seed): the content
    digest must refuse to splice the two corpora into one store."""
    kb, emb, tok, chunks = kb_env
    s = PrecomputedStore(tmp_path / "s", dim=emb.dim, shard_rows=32)
    mkpipe(kb, emb, tok, wave=4, checkpoint_every=2).run(
        chunks, 40, store=s, seed=0)
    kb2 = build_kb("squad", seed=99, n_docs=6)
    chunks2 = [chunk_key(d.doc_id, d.text()) for d in kb2.docs]
    with pytest.raises(ValueError, match="DIFFERENT chunk contents"):
        mkpipe(kb2, emb, tok, wave=4, checkpoint_every=2).run(
            chunks2, 80, store=s, seed=0)
    s.close()


def test_resume_refuses_different_config(kb_env, tmp_path):
    """Same chunks, different embedder or generation config: resuming
    would splice two embedding spaces / decision regimes into one store."""
    kb, emb, tok, chunks = kb_env
    s = PrecomputedStore(tmp_path / "s", dim=emb.dim, shard_rows=32)
    mkpipe(kb, emb, tok, wave=4, checkpoint_every=2).run(
        chunks, 40, store=s, seed=0)

    class OtherEmbedder(HashEmbedder):
        pass

    with pytest.raises(ValueError, match="mismatched settings"):
        PrecomputePipeline(
            SyntheticOracleLM(kb), OtherEmbedder(), tok, GenCfg(dedup=True),
            PrecomputeCfg(wave=4, checkpoint_every=2)).run(
                chunks, 80, store=s, seed=0)
    with pytest.raises(ValueError, match="mismatched settings"):
        PrecomputePipeline(
            SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True,
                                                    s_th_gen=0.95),
            PrecomputeCfg(wave=4, checkpoint_every=2)).run(
                chunks, 80, store=s, seed=0)
    s.close()


def test_resume_refuses_foreign_or_modified_store(kb_env, tmp_path):
    kb, emb, tok, chunks = kb_env
    # a store with rows but no checkpoint is not resumable
    s = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    s.add_batch(emb.encode(["a?"]), ["a?"], ["a."])
    s.flush()
    with pytest.raises(ValueError, match="no .* checkpoint"):
        mkpipe(kb, emb, tok, wave=4).run(chunks, 10, store=s, seed=0)
    s.close()
    # rows added behind the checkpoint's back are detected
    s2 = PrecomputedStore(tmp_path / "s2", dim=emb.dim, shard_rows=32)
    mkpipe(kb, emb, tok, wave=4, checkpoint_every=2).run(
        chunks, 40, store=s2, seed=0)
    s2.add_batch(emb.encode(["rogue"]), ["rogue"], ["row"])
    s2.flush()
    with pytest.raises(ValueError, match="modified outside"):
        mkpipe(kb, emb, tok, wave=4, checkpoint_every=2).run(
            chunks, 80, store=s2, seed=0)
    s2.close()


# ---------------------------------------------------------------------------
# IncrementalIndex
# ---------------------------------------------------------------------------


def _unit_rows(n, d, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_incremental_flat_matches_bruteforce():
    x = _unit_rows(500, 64)
    idx = IncrementalIndex(64, flat_max_rows=10_000)
    for lo in range(0, 500, 37):          # ragged add batches
        idx.add(x[lo:lo + 37])
    assert idx.mode == "flat" and len(idx) == 500
    q = _unit_rows(8, 64, seed=1)
    np.testing.assert_allclose(idx.max_sim(q), (q @ x.T).max(axis=1),
                               atol=1e-5)


def test_incremental_ivf_transition_finds_duplicates():
    x = _unit_rows(600, 64)
    idx = IncrementalIndex(64, flat_max_rows=128)
    idx.add(x)
    assert idx.mode == "ivf"
    assert idx.refits >= 2                # fits at 128 and 256, 512
    # the dedup-critical property: an exact duplicate of ANY stored row
    # probes the list holding its twin (same inner-product metric for
    # assignment and probing), so max_sim ~= 1
    assert float(idx.max_sim(x[::71]).min()) > 0.999


def test_incremental_state_independent_of_add_batching():
    """Deterministic split-at-threshold refits: the index state depends
    only on the row sequence, not on how adds were batched — the property
    the resume path's shard-at-a-time rebuild relies on."""
    x = _unit_rows(400, 32)
    a = IncrementalIndex(32, flat_max_rows=100)
    a.add(x)                               # one giant add
    b = IncrementalIndex(32, flat_max_rows=100)
    for lo in range(0, 400, 13):           # many ragged adds
        b.add(x[lo:lo + 13])
    np.testing.assert_array_equal(a.centroids, b.centroids)
    q = _unit_rows(6, 32, seed=2)
    np.testing.assert_array_equal(a.max_sim(q), b.max_sim(q))


# ---------------------------------------------------------------------------
# IVFIndex: clamp + persistence
# ---------------------------------------------------------------------------


def test_ivf_nlists_clamped_to_rows():
    """Regression: n_lists > rows used to crash k-means seeding
    (jax.random.choice with replace=False)."""
    x = _unit_rows(5, 32)
    ivf = IVFIndex(x, n_lists=64, nprobe=8)
    assert ivf.n_lists == 5 and ivf.nprobe == 5
    v, i = ivf.search(x[:2], 3)
    vf, if_ = FlatIndex(x).search(x[:2], 3)
    np.testing.assert_allclose(v, vf, atol=1e-5)
    np.testing.assert_array_equal(i, if_)


def test_ivf_save_load_roundtrip(tmp_path):
    x = _unit_rows(400, 48)
    ivf = IVFIndex(x, n_lists=16, nprobe=8, seed=3)
    path = ivf.save(tmp_path / "idx.npz")
    loaded = IVFIndex.load(path, x)
    assert loaded.loaded_from == str(path)
    q = _unit_rows(10, 48, seed=4)
    v1, i1 = ivf.search(q, 5)
    v2, i2 = loaded.search(q, 5)
    np.testing.assert_allclose(v1, v2, atol=1e-6)
    np.testing.assert_array_equal(i1, i2)


def test_auto_index_cache_skips_kmeans(kb_env, tmp_path, monkeypatch):
    kb, emb, tok, chunks = kb_env
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim, shard_rows=64)
    qs = [f"q {i} about {i % 13}" for i in range(300)]
    store.add_batch(emb.encode(qs), qs, ["r"] * 300)
    store.flush()

    built = auto_index(store, cache_dir=store.root, flat_max_rows=64)
    assert isinstance(built, IVFIndex) and built.loaded_from is None
    assert (store.root / "index_ivf.npz").exists()

    def bomb(*a, **k):
        raise AssertionError("k-means re-ran despite a valid cache")
    monkeypatch.setattr(RI, "kmeans", bomb)
    loaded = auto_index(store, cache_dir=store.root, flat_max_rows=64)
    assert loaded.loaded_from is not None
    q = emb.encode(qs[:5])
    v1, i1 = built.search(q, 3)
    v2, i2 = loaded.search(q, 3)
    np.testing.assert_allclose(v1, v2, atol=1e-6)
    np.testing.assert_array_equal(i1, i2)
    monkeypatch.undo()

    # stale cache (store grew) forces a rebuild, not a wrong load
    qs2 = [f"new q {i}" for i in range(40)]
    store.add_batch(emb.encode(qs2), qs2, ["r"] * 40)
    store.flush()
    rebuilt = auto_index(store, cache_dir=store.root, flat_max_rows=64)
    assert rebuilt.loaded_from is None and len(rebuilt) == 340
    store.close()


def test_auto_index_cache_detects_content_drift(kb_env, tmp_path):
    """Same row count, different vectors: the content fingerprint must
    force a rebuild instead of silently serving a stale fit."""
    kb, emb, tok, chunks = kb_env

    def mkstore(root, prefix):
        s = PrecomputedStore(root, dim=emb.dim)
        qs = [f"{prefix} question {i} about {i % 13}" for i in range(300)]
        s.add_batch(emb.encode(qs), qs, ["r"] * 300)
        s.flush()
        return s

    a = mkstore(tmp_path / "a", "alpha")
    auto_index(a, cache_dir=a.root, flat_max_rows=64)
    a.close()
    # same-sized store with different content inherits the cache file
    b = mkstore(tmp_path / "b", "beta")
    (tmp_path / "b" / "index_ivf.npz").write_bytes(
        (tmp_path / "a" / "index_ivf.npz").read_bytes())
    idx = auto_index(b, cache_dir=b.root, flat_max_rows=64)
    assert idx.loaded_from is None, "stale fit served for drifted content"
    b.close()


def test_fresh_store_truncates_orphan_text(kb_env, tmp_path):
    """A build killed before its first flush leaves text rows but no
    manifest; creating a fresh store over that directory must not bake
    the orphan rows into the new store."""
    kb, emb, tok, chunks = kb_env
    root = tmp_path / "s"
    root.mkdir()
    (root / "text.jsonl").write_text('{"q": "orphan", "r": "row"}\n' * 5)
    store = PrecomputedStore(root, dim=emb.dim)
    store.add_batch(emb.encode(["a?"]), ["a?"], ["a."])
    store.flush()
    store.close()
    st2 = PrecomputedStore.open_(root)
    assert st2.count == 1
    assert st2.get_pair(0) == ("a?", "a.")
    assert b"orphan" not in (root / "text.jsonl").read_bytes()
    st2.close()


# ---------------------------------------------------------------------------
# Store: lazy multi-shard embeddings + crash recovery
# ---------------------------------------------------------------------------


def test_multishard_embeddings_stay_memmapped(kb_env, tmp_path):
    """Regression: embeddings(mmap=True) used to np.concatenate every
    shard into RAM, defeating the memmap for multi-shard stores."""
    kb, emb, tok, chunks = kb_env
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim, shard_rows=8)
    qs = [f"query number {i}" for i in range(30)]
    E = emb.encode(qs)
    store.add_batch(E, qs, ["r"] * 30)
    store.flush()

    v = store.embeddings()
    assert isinstance(v, ShardedEmbeddings)
    assert len(list(v.iter_shards())) == 4            # 8+8+8+6
    assert all(isinstance(p, np.memmap) for p in v.iter_shards()), \
        "a shard was materialized in RAM"
    assert v.shape == (30, emb.dim)
    ref = E.astype(np.float16).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(v, np.float32), ref)
    np.testing.assert_array_equal(np.asarray(v[5:21], np.float32),
                                  ref[5:21])
    np.testing.assert_array_equal(
        np.asarray(v.take([0, 9, 17, 29]), np.float32),
        ref[[0, 9, 17, 29]])
    # ndarray-compatible indexing semantics: negatives wrap, OOB raises,
    # boolean masks select (take used to return uninitialized memory)
    np.testing.assert_array_equal(np.asarray(v[-1], np.float32), ref[-1])
    np.testing.assert_array_equal(
        np.asarray(v.take([-2, 5]), np.float32), ref[[-2, 5]])
    mask = np.zeros(30, bool)
    mask[[2, 28]] = True
    np.testing.assert_array_equal(np.asarray(v[mask], np.float32),
                                  ref[mask])
    with pytest.raises(IndexError):
        v.take([30])
    with pytest.raises(IndexError):
        v.take([-31])
    with pytest.raises(IndexError):
        v[np.zeros(7, bool)]
    # pending (unflushed) rows are part of the view too
    store.add_batch(E[:3], qs[:3], ["r"] * 3)
    assert store.embeddings().shape == (33, emb.dim)
    # and index builds over the view match a dense build
    vflat, iflat = FlatIndex(store.embeddings()).search(E[:4], 3)
    vref, iref = FlatIndex(np.concatenate([ref, ref[:3]])).search(E[:4], 3)
    np.testing.assert_allclose(vflat, vref, atol=1e-6)
    np.testing.assert_array_equal(iflat, iref)
    store.close()


def test_store_truncates_uncommitted_text_on_open(kb_env, tmp_path):
    kb, emb, tok, chunks = kb_env
    store = PrecomputedStore(tmp_path / "s", dim=emb.dim)
    qs = ["a?", "b?"]
    store.add_batch(emb.encode(qs), qs, ["a.", "b."])
    store.flush()
    committed = (tmp_path / "s" / "text.jsonl").read_bytes()
    # a killed writer's un-flushed appends
    with open(tmp_path / "s" / "text.jsonl", "a") as f:
        f.write('{"q": "torn', )
    store._text_f.close()

    st2 = PrecomputedStore.open_(tmp_path / "s")
    assert (tmp_path / "s" / "text.jsonl").read_bytes() == committed
    assert st2.get_pair(1) == ("b?", "b.")
    st2.add_batch(emb.encode(["c?"]), ["c?"], ["c."])   # appends still work
    st2.flush()
    assert st2.get_pair(2) == ("c?", "c.")
    st2.close()
