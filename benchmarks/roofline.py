"""Roofline table from the dry-run JSONs (experiments/dryrun/*.json).

Prints the per-(arch x shape x mesh) three-term roofline and writes the
markdown table EXPERIMENTS.md §Roofline embeds. Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
from __future__ import annotations

import json
from pathlib import Path

DRY = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells():
    cells = []
    for p in sorted(DRY.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def fmt_row(c):
    r = c.get("roofline", {})
    m = c.get("memory", {})
    if not r:
        return (f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                f"{c['status']} | | | | | | |")
    return ("| {arch} | {shape} | {mesh} | ok | {ct:.4f} | {mt:.4f} | "
            "{lt:.4f} | {dom} | {uf:.2f} | {rf:.3f} |".format(
                arch=c["arch"], shape=c["shape"], mesh=c["mesh"],
                ct=r["compute_s"], mt=r["memory_s"], lt=r["collective_s"],
                dom=r["dominant"].replace("_s", ""),
                uf=r.get("useful_flops_frac", 0.0),
                rf=r.get("roofline_frac", 0.0)))


def main():
    cells = load_cells()
    if not cells:
        print("roofline,no dryrun results found — run repro.launch.dryrun")
        return {"rows": 0}
    hdr = ("| arch | shape | mesh | status | compute_s | memory_s | "
           "collective_s | bound | useful_FLOPs | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr] + [fmt_row(c) for c in cells]
    md = "\n".join(lines)
    out = DRY.parent / "roofline_table.md"
    out.write_text(md + "\n")
    ok = [c for c in cells if c.get("roofline")]
    print("name,cells,ok,skipped_or_failed")
    print(f"roofline,{len(cells)},{len(ok)},{len(cells) - len(ok)}")
    for c in cells:
        r = c.get("roofline", {})
        if r:
            print(f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
                  f"{r['dominant']},{r['roofline_frac']:.3f}")
        else:
            print(f"roofline,{c['arch']},{c['shape']},{c['mesh']},"
                  f"{c['status'][:40]},-")
    return {"rows": len(cells), "table": str(out)}


if __name__ == "__main__":
    main()
