"""Pallas TPU kernel: tiled MIPS + per-tile top-k (the StorInfer hot spot).

The paper scans a DiskANN graph on CPU; on TPU the same search is a matmul
(DESIGN.md §3): the store shard streams through VMEM in (TILE_N, D) blocks,
each block scoring against the resident query block on the MXU, followed by
an on-chip iterative top-k over the tile. The host-side combine (ops.py)
reduces the (n_tiles, Q, K) candidates with one final lax.top_k —
O(n_tiles * K) per query, independent of N.

Tiling:
  q   : (Q, D)       resident in VMEM for the whole grid (Q <= ~1024)
  x   : (TILE_N, D)  one store tile per grid step (128-aligned)
  out : (Q, K) vals + (Q, K) idx per tile, written to grid slot i

VMEM working set per step ~= Q*D + TILE_N*D + Q*TILE_N floats; defaults
(Q<=256, TILE_N=512, D=384) ~ 1 MB — far under the ~16 MB v5e VMEM budget;
the MXU sees (Q x D) @ (D x TILE_N) with D padded to a lane multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _mips_kernel(q_ref, x_ref, vals_ref, idx_ref, *, k, tile_n, n_real):
    i = pl.program_id(0)
    q = q_ref[...]                                    # (Q, D)
    x = x_ref[...]                                    # (TILE_N, D)
    s = jnp.dot(q, x.T, preferred_element_type=jnp.float32)  # (Q, TILE_N)
    # mask padded store rows (beyond n_real)
    row_global = i * tile_n + jax.lax.broadcasted_iota(jnp.int32,
                                                       s.shape, 1)
    s = jnp.where(row_global < n_real, s, NEG)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    for kk in range(k):                               # iterative top-k
        m = jnp.max(s, axis=1)                        # (Q,)
        a = jnp.argmax(s, axis=1).astype(jnp.int32)   # (Q,)
        vals_ref[0, :, kk] = m
        idx_ref[0, :, kk] = a
        s = jnp.where(cols == a[:, None], NEG, s)


def mips_topk_pallas(q, x, k, *, tile_n=512, interpret=True):
    """q: (Q, D) f32; x: (N, D) f32. Returns per-tile candidates
    (vals (nt, Q, k), idx-global (nt, Q, k))."""
    Q, D = q.shape
    N = x.shape[0]
    nt = -(-N // tile_n)
    N_pad = nt * tile_n
    if N_pad != N:
        x = jnp.pad(x, ((0, N_pad - N), (0, 0)))
    Dp = -(-D // 128) * 128                           # lane alignment
    if Dp != D:
        q = jnp.pad(q, ((0, 0), (0, Dp - D)))
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))

    kernel = functools.partial(_mips_kernel, k=k, tile_n=tile_n, n_real=N)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((Q, Dp), lambda i: (0, 0)),        # q resident
            pl.BlockSpec((tile_n, Dp), lambda i: (i, 0)),   # x streamed
        ],
        out_specs=[
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt, Q, k), jnp.float32),
            jax.ShapeDtypeStruct((nt, Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
    # per-tile local idx -> global row ids
    offs = (jnp.arange(nt, dtype=jnp.int32) * tile_n)[:, None, None]
    return vals, idx + offs
