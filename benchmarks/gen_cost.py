"""§4 generation-cost numbers: seconds per precomputed pair (mean and the
discard-inflated max), plus the REAL-JAX-LM timing for the same loop (the
paper's 0.3 s/pair - 0.6 s/pair max was LLM-bound on an H100; our oracle
generator is microseconds-bound, so the JAX-LM row is the honest analogue).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import build_setup, out_write
from repro.configs import get_config, reduced
from repro.api import make_embedder
from repro.core.generator import GenCfg, QueryGenerator, chunk_key
from repro.core.kb import build_kb
from repro.core.tokenizer import Tokenizer
from repro.models import model as M
from repro.serving.engine import Engine
from repro.serving.lm import TinyJaxLM


def main():
    # oracle-LM generation cost (from the cached table1 runs)
    setup = build_setup("squad", dedup=True)
    st = setup["gen_stats"]

    # real-JAX-LM generation cost on a handful of pairs
    kb = build_kb("squad", n_docs=4)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=512)
    cfg = dataclasses.replace(reduced(get_config("qwen3-1.7b")),
                              vocab_size=tok.vocab_size, n_layers=2)
    params = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    eng = Engine(cfg, params, tok, M.RunCfg(attn_impl="naive", remat=False),
                 max_len=160, chunk=8)
    lm = TinyJaxLM(eng)
    gen = QueryGenerator(lm, make_embedder("hash"), tok,
                         GenCfg(dedup=True, s_th_gen=0.995))
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    t0 = time.perf_counter()
    qs, rs, _, jst = gen.generate(chunks, 6, seed=0)
    jax_s_per_pair = (time.perf_counter() - t0) / max(len(qs), 1)

    payload = {
        "oracle_sec_per_pair": st["sec_per_pair"],
        "oracle_max_wave_seconds": st["max_wave_seconds"],
        "oracle_discard_frac": st["discarded"] / max(
            st["generated"] + st["discarded"], 1),
        "jaxlm_sec_per_pair_cpu": jax_s_per_pair,
        "paper": {"sec_per_pair": 0.3, "max_sec_per_pair": 0.6},
    }
    out_write("gen_cost", payload)
    print("name,metric,value")
    for k, v in payload.items():
        if k != "paper":
            print(f"gen_cost,{k},{v}")
    return payload


if __name__ == "__main__":
    main()
