"""StorInfer core tests: generator invariants (hypothesis), store roundtrip,
index exactness, metrics properties, runtime hit/miss/cancellation."""
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # degrade to a fixed deterministic sample
    from _hypothesis_fallback import given, settings, st

from repro.core import metrics as MX
from repro.core.embedder import HashEmbedder
from repro.core.generator import (GenCfg, QueryGenerator, SyntheticOracleLM,
                                  chunk_key)
from repro.core.index import FlatIndex, IVFIndex
from repro.core.kb import build_kb, sample_user_queries
from repro.core.store import PrecomputedStore
from repro.core.tokenizer import Tokenizer


@pytest.fixture(scope="module")
def kb_env():
    kb = build_kb("squad", n_docs=8)
    emb = HashEmbedder()
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    chunks = [chunk_key(d.doc_id, d.text()) for d in kb.docs]
    return kb, emb, tok, chunks


# ---------------------------------------------------------------------------
# Generator (§3.2)
# ---------------------------------------------------------------------------


def test_dedup_invariant_no_near_duplicates(kb_env):
    kb, emb, tok, chunks = kb_env
    gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True))
    qs, rs, es, stats = gen.generate(chunks, 150, seed=0)
    sims = es @ es.T - np.eye(len(es))
    assert sims.max() < 0.99, "accepted pair above S_th_Gen"
    assert stats.discarded > 0, "dedup never triggered (test too easy)"


def test_random_baseline_has_duplicates(kb_env):
    kb, emb, tok, chunks = kb_env
    gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok,
                         GenCfg(dedup=False))
    qs, _, es, stats = gen.generate(chunks, 150, seed=0)
    assert stats.discarded == 0
    sims = es @ es.T - np.eye(len(es))
    assert sims.max() >= 0.99, "random generation produced no duplicates?"


def test_adaptive_sampling_raises_temperature(kb_env):
    kb, emb, tok, chunks = kb_env
    gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok, GenCfg(dedup=True))
    _, _, _, stats = gen.generate(chunks, 200, seed=1)
    assert stats.temp_final > 0.7, "temperature never increased"
    assert stats.temp_final <= 1.0 + 1e-9, "temperature exceeded cap"


@settings(max_examples=25, deadline=None)
@given(st.integers(64, 512), st.lists(st.integers(1, 60), min_size=0,
                                      max_size=30))
def test_masking_budget_property(max_ctx, q_lens):
    """Adaptive query masking: only COMPLETE queries, never over budget."""
    kb = build_kb("squad", n_docs=2)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs])
    gen = QueryGenerator(SyntheticOracleLM(kb), HashEmbedder(), tok,
                         GenCfg(max_ctx=max_ctx))
    chunk = chunk_key(0, kb.docs[0].text())
    recent = [" ".join(["word"] * n) for n in q_lens]
    chosen = gen.select_masked(recent, chunk)
    budget = max_ctx - tok.count(chunk) - gen.cfg.scaffold_tokens
    assert sum(tok.count(q) for q in chosen) <= max(budget, 0)
    for q in chosen:
        assert q in recent


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_reopen(kb_env, tmp_path):
    kb, emb, tok, chunks = kb_env
    store = PrecomputedStore(tmp_path / "s", dim=384)
    qs = ["what is a?", "what is b?", "tell me c"]
    rs = ["a is 1.", "b is 2.", "c is 3."]
    store.add_batch(emb.encode(qs), qs, rs)
    store.flush()
    st2 = PrecomputedStore.open_(tmp_path / "s")
    assert st2.count == 3
    for i, (q, r) in enumerate(zip(qs, rs)):
        assert st2.get_pair(i) == (q, r)
    e = st2.embeddings()
    assert e.shape == (3, 384)
    sb = st2.storage_bytes()
    assert sb["index_bytes"] > 0 and sb["metadata_bytes"] > 0


def test_store_reopen_then_append(kb_env, tmp_path):
    """Regression: ``open_`` used to reopen text.jsonl read-only, so
    add_batch on a reopened store (the §3.1 write-back path) crashed."""
    kb, emb, tok, chunks = kb_env
    qs1, rs1 = ["first q"], ["first r."]
    with PrecomputedStore(tmp_path / "s", dim=384) as store:
        store.add_batch(emb.encode(qs1), qs1, rs1)
    assert store.closed           # context manager flushed + closed

    st2 = PrecomputedStore.open_(tmp_path / "s")
    qs2, rs2 = ["second q", "third q"], ["second r.", "third r."]
    st2.add_batch(emb.encode(qs2), qs2, rs2)   # append after reopen
    st2.flush()
    assert st2.count == 3
    st2.close()
    st2.close()                   # close is idempotent

    st3 = PrecomputedStore.open_(tmp_path / "s")
    allq, allr = qs1 + qs2, rs1 + rs2
    for i in range(3):
        assert st3.get_pair(i) == (allq[i], allr[i])
    assert st3.embeddings().shape == (3, 384)
    st3.close()


# ---------------------------------------------------------------------------
# Indexes
# ---------------------------------------------------------------------------


def test_ivf_recall_close_to_flat():
    # clustered data (the regime IVF is built for — query embeddings of
    # paraphrase families cluster tightly)
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(32, 64)).astype(np.float32)
    x = (centers[rng.integers(0, 32, 2000)]
         + 0.15 * rng.normal(size=(2000, 64)).astype(np.float32))
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    q = x[rng.choice(2000, 50)] + 0.02 * rng.normal(size=(50, 64)).astype(
        np.float32)
    flat = FlatIndex(x)
    ivf = IVFIndex(x, n_lists=32, nprobe=8)
    vf, idf = flat.search(q, 10)
    vi, idi = ivf.search(q, 10)
    recall = np.mean([len(set(a) & set(b)) / 10
                      for a, b in zip(idf, idi)])
    assert recall > 0.8, recall


def test_flat_index_kernel_path_matches():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(300, 48)).astype(np.float32)
    q = rng.normal(size=(4, 48)).astype(np.float32)
    v1, i1 = FlatIndex(x).search(q, 5)
    v2, i2 = FlatIndex(x, use_kernel=True).search(q, 5)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_metrics_known_values():
    assert MX.unigram_f1("a b c", "a b c") == 1.0
    assert MX.unigram_f1("a b", "c d") == 0.0
    assert MX.rouge_l_f1("the cat sat", "the cat sat") == 1.0
    assert 0 < MX.rouge_l_f1("the cat sat down", "the cat lay down") < 1.0
    assert MX.bert_score_f1("hello world", "hello world") > 0.99


@settings(max_examples=30, deadline=None)
@given(st.text("abcde ", min_size=1, max_size=30),
       st.text("abcde ", min_size=1, max_size=30))
def test_metrics_bounded(a, b):
    for m in (MX.unigram_f1, MX.rouge_l_f1):
        v = m(a, b)
        assert -1e-9 <= v <= 1 + 1e-9
        assert abs(m(a, b) - m(b, a)) < 1e-9  # F1 symmetric


# ---------------------------------------------------------------------------
# Hit-rate sanity: dedup beats random at equal store size (Table 1 trend)
# ---------------------------------------------------------------------------


def test_dedup_beats_random_hit_rate(kb_env):
    kb, emb, tok, chunks = kb_env
    user = sample_user_queries(kb, 400, seed=7)
    rates, distinct = {}, {}
    for dedup in (False, True):
        gen = QueryGenerator(SyntheticOracleLM(kb), emb, tok,
                             GenCfg(dedup=dedup))
        qs, rs, es, _ = gen.generate(chunks, 400, seed=2)
        idx = FlatIndex(es)
        ue = emb.encode([q for q, _ in user])
        v, _ = idx.search(ue, 1)
        rates[dedup] = float(np.mean(v[:, 0] >= 0.9))
        distinct[dedup] = len(set(qs))
    # coverage strictly improves; hit rate within statistical tolerance at
    # this small store size (benchmarks/table1 checks the 8k-pair regime)
    assert distinct[True] >= distinct[False], distinct
    assert rates[True] >= rates[False] - 0.02, rates
