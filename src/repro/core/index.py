"""MIPS indexes over the precomputed-query embeddings.

TPU adaptation of the paper's DiskANN: graph-ANN pointer-chasing is
hostile to the MXU/HBM burst model, so the index is a batched tiled MIPS
scan — a matmul, the single most roofline-friendly op on the platform —
with IVF coarse pruning for sub-linear probes and a mesh-sharded variant
(rows over "model", distributed top-k) for pod-scale stores.

  FlatIndex    — exact brute MIPS (jnp matmul + top_k; the Pallas
                 ``mips_topk`` kernel implements the same contract on TPU).
  IVFIndex     — k-means coarse quantizer, scans nprobe lists.
  ShardedIndex — rows sharded over a mesh axis, local top-k + all-gather
                 combine (repro.distributed.topk).

``auto_index`` picks between the three from store size and mesh
availability (see ``select_tier`` for the exact boundaries) so callers —
the batched runtime in particular — never hard-code a tier.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class FlatIndex:
    """Exact MIPS. ``use_kernel`` routes the local scan through the Pallas
    mips_topk op (interpret mode on CPU)."""

    def __init__(self, embs: np.ndarray, use_kernel: bool = False):
        self.embs = jnp.asarray(np.asarray(embs, np.float32))
        self.use_kernel = use_kernel
        self._search = jax.jit(self._search_impl, static_argnums=(2,))

    def _search_impl(self, q, embs, k):
        if self.use_kernel:
            from repro.kernels.ops import mips_topk
            return mips_topk(q, embs, k)
        s = q @ embs.T
        return jax.lax.top_k(s, k)

    def search(self, queries: np.ndarray, k: int):
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = self._search(q, self.embs, k)
        return np.asarray(v), np.asarray(i)

    def __len__(self):
        return int(self.embs.shape[0])


# ---------------------------------------------------------------------------
# IVF (k-means coarse quantizer)
# ---------------------------------------------------------------------------


def kmeans(x: jnp.ndarray, n_clusters: int, iters: int = 10, seed: int = 0):
    """Plain Lloyd's on the device. Returns (centroids, assignment)."""
    key = jax.random.PRNGKey(seed)
    n = x.shape[0]
    init = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = x[init]

    @jax.jit
    def step(cent):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None, :])
        a = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(a, n_clusters, dtype=x.dtype)
        sums = oh.T @ x
        counts = oh.sum(0)[:, None]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cent)
        return new, a

    for _ in range(iters):
        cent, assign = step(cent)
    return cent, assign


class IVFIndex:
    """IVF-Flat: coarse k-means, probe top-``nprobe`` lists, exact scan.

    Padded list layout (lists, cap, dim) so the probe scan is one gather +
    batched matmul — TPU-friendly, no ragged pointers.
    """

    def __init__(self, embs: np.ndarray, n_lists: int = 64, nprobe: int = 8,
                 seed: int = 0):
        x = jnp.asarray(np.asarray(embs, np.float32))
        self.n_total = int(x.shape[0])
        self.nprobe = min(nprobe, n_lists)
        self.n_lists = n_lists
        cent, assign = kmeans(x, n_lists, seed=seed)
        self.centroids = cent
        assign = np.asarray(assign)
        cap = max(int(np.max(np.bincount(assign, minlength=n_lists))), 1)
        N, D = x.shape
        buf = np.zeros((n_lists, cap, D), np.float32)
        ids = np.full((n_lists, cap), -1, np.int32)
        fill = np.zeros(n_lists, np.int32)
        xe = np.asarray(x)
        for row, a in enumerate(assign):
            buf[a, fill[a]] = xe[row]
            ids[a, fill[a]] = row
            fill[a] += 1
        self.lists = jnp.asarray(buf)
        self.ids = jnp.asarray(ids)
        self._search = jax.jit(self._search_impl, static_argnums=(1,))

    def _search_impl(self, q, k):
        # 1. coarse: score centroids
        cs = q @ self.centroids.T                          # (Q, n_lists)
        _, probe = jax.lax.top_k(cs, self.nprobe)          # (Q, nprobe)
        # 2. gather probed lists and scan
        cand = self.lists[probe]                           # (Q,np,cap,D)
        cand_ids = self.ids[probe]                         # (Q,np,cap)
        s = jnp.einsum("qd,qpcd->qpc", q, cand)
        s = jnp.where(cand_ids < 0, -jnp.inf, s)
        Q = q.shape[0]
        s = s.reshape(Q, -1)
        ci = cand_ids.reshape(Q, -1)
        v, pos = jax.lax.top_k(s, k)
        return v, jnp.take_along_axis(ci, pos, axis=1)

    def search(self, queries: np.ndarray, k: int):
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = self._search(q, k)
        return np.asarray(v), np.asarray(i)

    def __len__(self):
        return self.n_total

    def reconstruct(self) -> np.ndarray:
        """The indexed rows, (N, D), rebuilt from the padded list layout
        (row order restored from the stored ids)."""
        lists = np.asarray(self.lists)
        ids = np.asarray(self.ids)
        out = np.zeros((self.n_total, lists.shape[-1]), np.float32)
        valid = ids >= 0
        out[ids[valid]] = lists[valid]
        return out

    def recall_vs_flat(self, queries, k: int = 10) -> float:
        """Mean recall@k of this IVF index against an exact flat scan over
        the same rows. 1.0 means the nprobe pruning lost nothing for these
        queries; ``auto_index`` callers use this to validate an IVF choice.

        The flat reference is built on demand from ``reconstruct()`` and
        discarded — this is a diagnostic, not a serving path, so the index
        doesn't pay a permanent 2x memory cost for it.
        """
        q = np.asarray(queries, np.float32)
        _, flat_ids = FlatIndex(self.reconstruct()).search(q, k)
        _, ivf_ids = self.search(q, k)
        hits = [len(set(f.tolist()) & set(i.tolist())) / k
                for f, i in zip(flat_ids, ivf_ids)]
        return float(np.mean(hits))


class ShardedIndex:
    """Mesh-sharded exact MIPS: rows over ``shard_axis``, distributed top-k."""

    def __init__(self, embs: np.ndarray, mesh, shard_axis: str = "model"):
        from jax.sharding import NamedSharding, PartitionSpec as P
        n_sh = mesh.shape[shard_axis]
        N, D = embs.shape
        pad = (-N) % n_sh
        if pad:
            embs = np.concatenate(
                [embs, np.full((pad, D), -1e4, embs.dtype)], axis=0)
        self.n_real = N
        self.mesh = mesh
        self.shard_axis = shard_axis
        sh = NamedSharding(mesh, P(shard_axis, None))
        self.embs = jax.device_put(
            jnp.asarray(np.asarray(embs, np.float32)), sh)

    def search(self, queries: np.ndarray, k: int):
        from repro.distributed.topk import sharded_mips_topk
        q = jnp.asarray(np.asarray(queries, np.float32))
        v, i = sharded_mips_topk(q, self.embs, k, mesh=self.mesh,
                                 shard_axis=self.shard_axis)
        return np.asarray(v), np.asarray(i)

    def __len__(self):
        return self.n_real


# ---------------------------------------------------------------------------
# Tier auto-selection
# ---------------------------------------------------------------------------

# Below this row count an exact flat scan is one small matmul and beats any
# pruning overhead; above it IVF's nprobe/n_lists scan fraction wins. The
# paper's 150K-pair store lands in the IVF tier.
FLAT_MAX_ROWS = 32768
# Sharding only pays once each shard is a non-trivial scan.
SHARD_MIN_ROWS = 4 * FLAT_MAX_ROWS


def select_tier(n_rows: int, mesh_axis_size: int = 1, *,
                flat_max_rows: int = FLAT_MAX_ROWS,
                shard_min_rows: int = SHARD_MIN_ROWS) -> str:
    """Pure tier decision: ``"flat" | "ivf" | "sharded"``.

    Separated from ``auto_index`` so the boundary logic is unit-testable
    without building real indexes (or a real multi-device mesh).
    """
    if n_rows <= 0:
        raise ValueError("cannot index an empty store")
    if mesh_axis_size > 1 and n_rows >= shard_min_rows:
        return "sharded"
    if n_rows <= flat_max_rows:
        return "flat"
    return "ivf"


def ivf_params(n_rows: int) -> Tuple[int, int]:
    """(n_lists, nprobe) heuristic: sqrt-N lists, probe ~1/8 of them (at
    least 8) — keeps the scanned fraction roughly constant as N grows."""
    n_lists = max(16, int(round(float(n_rows) ** 0.5)))
    nprobe = max(8, n_lists // 8)
    return n_lists, min(nprobe, n_lists)


def auto_index(store, mesh=None, *, shard_axis: str = "model",
               use_kernel: Optional[bool] = None,
               flat_max_rows: int = FLAT_MAX_ROWS,
               shard_min_rows: int = SHARD_MIN_ROWS, seed: int = 0):
    """Build the right index tier for ``store`` (a PrecomputedStore, or any
    object with ``.embeddings()``, or a raw (N, D) array).

    ``use_kernel=None`` routes the flat scan through the Pallas mips_topk
    kernel when running on a real TPU and keeps the plain jnp path (faster
    than interpret mode) on CPU.
    """
    if hasattr(store, "embeddings"):
        embs = np.asarray(store.embeddings(), np.float32)
    else:
        embs = np.asarray(store, np.float32)
    axis_size = 1
    if mesh is not None:
        try:
            axis_size = int(mesh.shape[shard_axis])
        except (KeyError, TypeError):
            axis_size = 1
    tier = select_tier(embs.shape[0], axis_size,
                       flat_max_rows=flat_max_rows,
                       shard_min_rows=shard_min_rows)
    if tier == "sharded":
        return ShardedIndex(embs, mesh, shard_axis=shard_axis)
    if tier == "ivf":
        n_lists, nprobe = ivf_params(embs.shape[0])
        return IVFIndex(embs, n_lists=n_lists, nprobe=nprobe, seed=seed)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    return FlatIndex(embs, use_kernel=use_kernel)
