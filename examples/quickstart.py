"""Quickstart: the whole StorInfer system through its one front door —
build a precomputed-query store from a knowledge base, then serve queries
against it, in five lines of API:

    kb = build_kb("squad", n_docs=25)
    with StorInfer.build(kb, SystemCfg(), path, n_pairs=1500) as si:
        result = si.query("what is the height of aurora bridge?")

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

from repro import StorInfer, SystemCfg
from repro.core.kb import build_kb, sample_user_queries


def main():
    # a knowledge base (stands in for the paper's SQuAD documents)
    kb = build_kb("squad", n_docs=25)

    with tempfile.TemporaryDirectory() as td:
        # OFFLINE: batched deduplicated query generation into the store
        # (checkpointed — rerunning after a kill resumes from the manifest)
        with StorInfer.build(kb, SystemCfg(), td, n_pairs=1500) as si:
            st = si.build_stats
            print(f"generated {st.generated} pairs in {st.waves} waves "
                  f"({st.discarded} near-duplicates discarded, "
                  f"{st.seconds:.1f}s, {st.pairs_per_sec:.0f} pairs/s); "
                  f"store = "
                  f"{si.store.storage_bytes()['total_bytes'] / 1e6:.2f} MB")

            # ONLINE: queries hit the store or fall through
            user = sample_user_queries(kb, 400, seed=5)
            hits = sum(si.query(q).hit for q, _ in user)
            print(f"hit rate @0.9 over {len(user)} user queries: "
                  f"{hits / len(user):.3f}")
            r = si.query(user[0][0])
            print(f"example: {user[0][0]!r}\n  -> [{r.source}] "
                  f"{r.response!r} (search {r.search_s * 1e3:.2f} ms)")
            s = si.stats()
            print(f"system: {s.store_rows} rows behind a {s.index_tier} "
                  f"index, {s.runtime.queries} queries served")


if __name__ == "__main__":
    main()
