"""Table 1: hit rate and effective latency per dataset, Random vs
Deduplicated generation, at S_th_Run = 0.9.

effective_latency = hit_rate * search_s + miss_rate * llm_s  (paper §4);
llm_s is the modeled H100/8B latency per dataset (same operating point as
Fig 3), search_s the measured store search. The paper's numbers for its
150K-pair stores are attached for comparison.
"""
from __future__ import annotations

from benchmarks.common import DATASETS, build_setup, hit_stats, out_write
from benchmarks.fig3_latency import CTX, N_PARAMS_8B, OUT_TOKENS
from repro.core import latency as L

S_TH_RUN = 0.9

PAPER = {  # dataset -> {mode: (hit_rate, latency_reduction_pct)}
    "squad": {"random": (0.180, 13.8), "dedup": (0.225, 17.3)},
    "narrativeqa": {"random": (0.080, 6.4), "dedup": (0.110, 8.8)},
    "triviaqa": {"random": (0.050, 4.7), "dedup": (0.080, 7.5)},
}


def main():
    rows = []
    for ds in DATASETS:
        llm_s = L.llm_latency(L.H100, N_PARAMS_8B, CTX[ds],
                              OUT_TOKENS)["total_s"]
        for dedup in (False, True):
            setup = build_setup(ds, dedup)
            hr, _, _, search_s = hit_stats(setup, S_TH_RUN)
            eff = L.effective_latency(hr, search_s, llm_s)
            red = 100.0 * (1 - eff / llm_s)
            mode = "dedup" if dedup else "random"
            rows.append({
                "dataset": ds, "mode": mode, "hit_rate": hr,
                "search_s": search_s, "llm_s": llm_s,
                "effective_latency_s": eff, "latency_reduction_pct": red,
                "paper_hit_rate": PAPER[ds][mode][0],
                "paper_reduction_pct": PAPER[ds][mode][1],
                "gen_stats": setup["gen_stats"],
            })
    payload = {"s_th_run": S_TH_RUN, "rows": rows}
    out_write("table1_hitrate", payload)
    print("name,dataset,mode,hit_rate,eff_latency_s,reduction_pct,"
          "paper_hit,paper_red")
    for r in rows:
        print(f"table1,{r['dataset']},{r['mode']},{r['hit_rate']:.3f},"
              f"{r['effective_latency_s']:.4f},"
              f"{r['latency_reduction_pct']:.1f},"
              f"{r['paper_hit_rate']},{r['paper_reduction_pct']}")
    # invariant the paper claims: dedup >= random on every dataset
    # (0.01 tolerance: on the flattest profiles the two tie statistically)
    for ds in DATASETS:
        hr = {r["mode"]: r["hit_rate"] for r in rows
              if r["dataset"] == ds}
        assert hr["dedup"] >= hr["random"] - 0.01, (ds, hr)
    return payload


if __name__ == "__main__":
    main()
