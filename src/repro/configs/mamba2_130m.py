"""Mamba2-130M pure SSM (SSD / state-space duality) [arXiv:2405.21060; unverified].

24L, d_model 768 (d_inner 1536, 24 SSD heads of 64), ssm_state 128, attn-free,
vocab 50280.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    rope_kind="none",
    tie_embeddings=True,
    norm_eps=1e-5,
))
