"""Synthetic knowledge bases + user-query distributions.

No QA datasets ship in this container, so the paper's SQuAD / NarrativeQA /
TriviaQA setups are reproduced as three synthetic KB profiles matching their
salient statistics for this system: context length per document (drives LLM
inference latency in Fig 3) and query predictability (drives hit rate in
Table 1 — SQuAD-like short factoid questions are most predictable,
TriviaQA-like trivia the least).

A KB is a set of documents; each document is a set of (entity, relation,
value) facts rendered to text. USER queries are drawn from a Zipf
distribution over facts x a paraphrase-template distribution + filler noise
— the "narrow or predictable query distribution" regime the paper targets
(§1). The offline generator sees the DOCUMENTS (not the user queries); its
job is to anticipate them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

ENTITIES = [
    "aurora bridge", "cedar falls", "doctor reyes", "the meridian institute",
    "lake halcyon", "professor tanaka", "the obsidian archive",
    "mount caldera", "the verdant coast", "captain ibarra", "new alexandria",
    "the silk consortium", "general okafor", "the amber accord",
    "port serrano", "the lumen foundry", "queen adelheid", "the iron canal",
    "senator volkov", "the coral senate", "engineer dubois",
    "the basalt citadel", "admiral chen", "the golden meridian",
    "judge okonkwo", "the crystal parliament", "bishop armand",
    "the copper exchange", "warden silva", "the azure expedition",
]
RELATIONS = [
    "height", "founder", "population", "construction year", "length",
    "capital", "author", "discovery date", "budget", "location", "leader",
    "purpose", "successor", "native language", "main export", "area",
    "chief rival", "founding charter", "patron", "climate",
]
VALUES = [
    "two hundred meters", "elena marchetti", "forty thousand", "1887",
    "twelve kilometers", "the northern quarter", "hassan el-badri", "1923",
    "nine million crowns", "the western escarpment", "director yuen",
    "flood control", "the second assembly", "old vareni", "refined cobalt",
    "three hundred hectares", "the harbor league", "the spring covenant",
    "the mercantile guild", "cool and wet",
]

# paraphrase templates for factoid questions about (entity, relation)
TEMPLATES = [
    "what is the {r} of {e}?",
    "tell me the {r} of {e}",
    "what's {e}'s {r}?",
    "do you know the {r} of {e}?",
    "i want to know the {r} of {e}",
    "can you give me {e}'s {r}?",
    "{e} {r}?",
    "please state the {r} of {e}",
    "what would be the {r} of {e}",
    "give the {r} for {e}",
]
# phrasings the offline generator does NOT anticipate — the miss mass that
# bounds achievable hit rate (real users paraphrase beyond any precomputed
# set; the per-dataset fraction models SQuAD < NarrativeQA < TriviaQA
# predictability, Table 1).
HARD_TEMPLATES = [
    "regarding {e}, i could use some information on its {r}",
    "been curious lately about how the {r} works out for {e}",
    "my colleague asked me yesterday about {e} and specifically the {r}",
    "if you had to look it up, where does {e} stand on {r}",
    "summarize whatever records exist concerning the {r} associated with "
    "{e}",
    "in the grand scheme of things, how should one think about {e} and "
    "its {r}",
]
FILLERS = ["", "", "", "hi, ", "hello, ", "quick question: ", "hey — ",
           "sorry to bother you, but "]


@dataclasses.dataclass
class Fact:
    entity: str
    relation: str
    value: str
    doc_id: int

    def statement(self) -> str:
        return f"the {self.relation} of {self.entity} is {self.value}."

    def answer(self) -> str:
        return (f"the {self.relation} of {self.entity} is {self.value}.")


@dataclasses.dataclass
class Document:
    doc_id: int
    facts: List[Fact]
    context_pad: int  # extra narrative tokens (dataset context length knob)

    def text(self) -> str:
        body = " ".join(f.statement() for f in self.facts)
        pad = " ".join(["the chronicle further records details"]
                       * max(self.context_pad // 5, 0))
        return (body + " " + pad).strip()


@dataclasses.dataclass
class KB:
    name: str
    docs: List[Document]
    facts: List[Fact]
    zipf_a: float          # user-query skew (lower = flatter = harder)
    template_skew: float   # concentration of paraphrase choice
    popularity: "np.ndarray" = None  # rank of each fact in the user Zipf
    hard_frac: float = 0.0           # unanticipatable-phrasing mass

    def doc_text(self, doc_id: int) -> str:
        return self.docs[doc_id].text()


# Dataset profiles: (docs, facts/doc, context pad tokens, zipf, tmpl skew,
# hard_frac = probability a user query uses an unanticipatable phrasing).
# Context pads mirror the relative context sizes of the paper's datasets
# (SQuAD short paragraphs < NarrativeQA summaries < TriviaQA evidence).
PROFILES = {
    "squad": dict(n_docs=200, facts_per_doc=8, context_pad=60,
                  zipf_a=1.3, template_skew=1.5, hard_frac=0.55),
    "narrativeqa": dict(n_docs=200, facts_per_doc=12, context_pad=400,
                        zipf_a=1.1, template_skew=1.0, hard_frac=0.75),
    "triviaqa": dict(n_docs=200, facts_per_doc=16, context_pad=1200,
                     zipf_a=0.9, template_skew=0.6, hard_frac=0.85),
}


def build_kb(name: str, seed: int = 0, n_docs: Optional[int] = None) -> KB:
    prof = PROFILES[name]
    # zlib.crc32, NOT hash(): python string hashing is randomized per
    # process, which would give every process a different "world" and
    # silently invalidate cross-process store caches.
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 1000)
    n_docs = n_docs or prof["n_docs"]
    docs, facts = [], []
    for d in range(n_docs):
        fs = []
        for _ in range(prof["facts_per_doc"]):
            f = Fact(entity=rng.choice(ENTITIES) + f" of district {d}",
                     relation=str(rng.choice(RELATIONS)),
                     value=str(rng.choice(VALUES)),
                     doc_id=d)
            fs.append(f)
            facts.append(f)
        docs.append(Document(d, fs, prof["context_pad"]))
    # fact popularity (user-query Zipf rank) is a property of the WORLD:
    # both the online user stream and a well-prompted generator LLM see the
    # same salience ordering — the "predictable query distribution" premise
    # (paper §1). rank[i] = Zipf rank of fact i.
    rank = rng.permutation(n_docs * prof["facts_per_doc"])
    return KB(name, docs, facts, prof["zipf_a"], prof["template_skew"],
              popularity=rank, hard_frac=prof["hard_frac"])


def render_query(fact: Fact, template_id: int, filler_id: int = 0) -> str:
    t = TEMPLATES[template_id % len(TEMPLATES)]
    return (FILLERS[filler_id % len(FILLERS)]
            + t.format(r=fact.relation, e=fact.entity))


def sample_user_queries(kb: KB, n: int, seed: int = 1):
    """The ONLINE query stream: Zipf over facts x skewed template choice.

    Returns list of (query_text, fact) — fact is the gold reference for
    quality metrics.
    """
    rng = np.random.default_rng(seed)
    nf = len(kb.facts)
    p = (kb.popularity + 1.0) ** -kb.zipf_a       # P(fact i) by its rank
    p /= p.sum()
    tp = np.arange(1, len(TEMPLATES) + 1, dtype=np.float64) \
        ** -kb.template_skew
    tp /= tp.sum()
    out = []
    for _ in range(n):
        f = kb.facts[rng.choice(nf, p=p)]
        if rng.random() < kb.hard_frac:
            t = rng.choice(len(HARD_TEMPLATES))
            q = HARD_TEMPLATES[t].format(r=f.relation, e=f.entity)
        else:
            t = rng.choice(len(TEMPLATES), p=tp)
            q = render_query(f, t, int(rng.choice(len(FILLERS))))
        out.append((q, f))
    return out
