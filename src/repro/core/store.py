"""Disk-backed precomputed query-response store (§3.3).

Layout on disk (root/): see docs/ARCHITECTURE.md for the full format table.
  manifest.json          — dim, dtype, count, shard list, shard_rows,
                           text_bytes (crash-recovery watermark), extra
                           (e.g. the precompute pipeline's ``gen_state``
                           resume checkpoint)
  emb_XXXX.npy           — embedding shards, (rows, dim) float16 memmap
                           (or int8 when ``emb_dtype="int8"``)
  emb_XXXX_scale.npy     — int8 stores only: the shard's per-row f32
                           dequant scales (rows,)
  text.jsonl             — one {"q": query, "r": response} per row
  offsets.npy            — byte offset of each row in text.jsonl
  index_ivf.npz          — optional persisted IVF index (auto_index cache)

Quantized stores (``emb_dtype="int8"``): rows are quantized symmetrically
per row — ``values = rint(row / scale)`` with ``scale = max|row| / 127`` —
as they are ingested, so a shard on disk is int8 values plus an f32 scale
per row (~26% of the fp32 bytes, ~51% of fp16; the paper's 830 MB edge
budget shrinks accordingly). Per-row quantization makes shard layout a
pure function of the row sequence (merging a partial tail shard is a
plain concatenation, no re-quantization), which is what keeps killed +
resumed builds byte-identical. ``embeddings()`` returns a
``QuantizedShardedEmbeddings`` view that dequantizes on access and
exposes the raw int8 parts for device upload (the int8 serving path in
core/index.py / kernels/mips_topk_int8.py). Old fp32/fp16 manifests are
untouched by any of this and load exactly as before.

Embeddings are the "index tier" (paper: 810 MB DiskANN index for 150K),
responses the "metadata tier" (paper: 20 MB); ``storage_bytes()`` reports
the same split for Fig 4 / §4. Appends flush shard-at-a-time; ``open_``
memory-maps the shards so a store larger than RAM still serves (the
storage-as-memory-tier premise of the paper, adapted: host RAM/NVMe is the
backing tier, device HBM the scan tier).

Crash safety: ``flush()`` writes offsets.npy and manifest.json atomically
(tmp + rename) and records the committed text.jsonl byte length; ``open_``
truncates any trailing bytes a killed writer appended past the last flush,
so a resumed build continues from exactly the committed prefix.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Iterator, List, Sequence, Tuple

import numpy as np

SHARD_ROWS = 32768


# ---------------------------------------------------------------------------
# Symmetric per-row int8 quantization (the ``emb_dtype="int8"`` store format
# and the query-side quantization of the int8 serving path)
# ---------------------------------------------------------------------------


def quantize_rows(embs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(n, d) float -> (int8 values (n, d), f32 scales (n,)).

    Symmetric per-row: ``scale = max|row| / 127``, ``values =
    rint(row / scale)`` (zero rows get scale 1 so dequant stays exact).
    Quantizing an already-round-tripped row reproduces it bit-for-bit —
    the max element maps back to exactly ±127 — which is what lets shard
    merges and resumed builds stay byte-identical without ever keeping
    the original f32 around."""
    embs = np.asarray(embs, np.float32)
    amax = np.abs(embs).max(axis=1) if embs.shape[0] else \
        np.zeros((0,), np.float32)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    vals = np.clip(np.rint(embs / scale[:, None]), -127, 127)
    return vals.astype(np.int8), scale


def dequantize_rows(vals: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Inverse of ``quantize_rows``: int8 (n, d) + f32 (n,) -> f32 (n, d)."""
    return np.asarray(vals, np.float32) * \
        np.asarray(scale, np.float32)[:, None]


def roundtrip_dtype(embs: np.ndarray, dtype) -> np.ndarray:
    """f32 embeddings as they will read back from a store of ``dtype`` —
    the dedup pipeline scores on this so an in-run index and one rebuilt
    from disk see bit-identical similarities (core/precompute.py)."""
    dtype = np.dtype(dtype)
    if dtype == np.int8:
        return dequantize_rows(*quantize_rows(embs))
    if dtype == np.float32:
        return np.asarray(embs, np.float32)
    return np.asarray(embs).astype(dtype).astype(np.float32)


class ShardedEmbeddings:
    """Lazy row-concatenated view over embedding shards.

    ``embeddings(mmap=True)`` used to ``np.concatenate`` every shard into
    RAM — defeating the memmap for exactly the multi-shard stores that need
    it. This view keeps the shards as-is (memmaps for flushed shards, small
    ndarrays for pending rows) and quacks enough like an (N, D) array for
    the index builders: ``shape``/``dtype``/``len``, row indexing/slicing,
    ``take`` (row gather that touches only the requested rows per shard),
    and ``np.asarray`` for callers that explicitly want a materialized copy.
    Index builds iterate ``iter_shards()`` so peak host memory is one shard,
    not the store.
    """

    def __init__(self, parts: List[np.ndarray], dim: int, dtype):
        self.parts = parts
        self.shape = (int(sum(p.shape[0] for p in parts)), dim)
        self.dtype = np.dtype(dtype)
        self.ndim = 2

    def __len__(self) -> int:
        return self.shape[0]

    def iter_shards(self) -> Iterator[np.ndarray]:
        yield from self.parts

    def __array__(self, dtype=None, copy=None):
        if not self.parts:
            return np.zeros(self.shape, dtype or self.dtype)
        out = np.concatenate([np.asarray(p) for p in self.parts], axis=0)
        return out.astype(dtype) if dtype is not None else out

    def _norm_rows(self, rows) -> np.ndarray:
        rows = np.asarray(rows)
        if rows.dtype == bool:
            if rows.shape[0] != self.shape[0]:
                raise IndexError(
                    f"boolean mask of length {rows.shape[0]} over "
                    f"{self.shape[0]} rows")
            rows = np.nonzero(rows)[0]
        rows = rows.astype(np.int64)
        n = self.shape[0]
        rows = np.where(rows < 0, rows + n, rows)
        if rows.size and (rows.min() < 0 or rows.max() >= n):
            raise IndexError(
                f"row index out of range for {n}-row embedding view")
        return rows

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        out = np.empty((rows.shape[0], self.shape[1]), self.dtype)
        lo = 0
        for p in self.parts:
            hi = lo + p.shape[0]
            m = (rows >= lo) & (rows < hi)
            if m.any():
                out[m] = np.asarray(p[rows[m] - lo])
            lo = hi
        return out

    def take(self, rows) -> np.ndarray:
        """Gather arbitrary rows (int array or boolean mask); reads only
        the requested rows from each shard. Negative indices wrap and
        out-of-range ones raise, matching ndarray semantics."""
        return self._gather(self._norm_rows(rows))

    def __getitem__(self, key):
        if isinstance(key, (int, np.integer)):
            return self.take(np.asarray([key]))[0]
        if isinstance(key, slice):
            return self.take(np.arange(*key.indices(self.shape[0])))
        return self.take(key)


class QuantizedShardedEmbeddings(ShardedEmbeddings):
    """Lazy view over int8 shards + per-row scales.

    Float consumers (index builders, dedup, benchmarks) see dequantized
    f32 through every inherited accessor (``take`` / slicing /
    ``iter_shards`` / ``np.asarray``), so a quantized store drops into
    any code written for float views. The quantized serving path reads
    the raw parts instead: ``iter_qshards()`` / ``take_q()`` hand
    (int8 values, f32 scales) to the device cache so only stored bytes
    ever cross the host→device link (core/index.DeviceStore)."""

    is_quantized = True

    def __init__(self, parts: List[np.ndarray], scales: List[np.ndarray],
                 dim: int):
        super().__init__(parts, dim, np.float32)   # consumers see f32
        self.scales = scales
        self.qdtype = np.dtype(np.int8)

    def iter_qshards(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        yield from zip(self.parts, self.scales)

    def iter_shards(self) -> Iterator[np.ndarray]:
        for p, s in zip(self.parts, self.scales):
            yield dequantize_rows(np.asarray(p), np.asarray(s))

    def __array__(self, dtype=None, copy=None):
        if not self.parts:
            return np.zeros(self.shape, dtype or self.dtype)
        out = np.concatenate(list(self.iter_shards()), axis=0)
        return out.astype(dtype) if dtype is not None else out

    def _gather_q(self, rows: np.ndarray):
        vals = np.empty((rows.shape[0], self.shape[1]), np.int8)
        scale = np.empty((rows.shape[0],), np.float32)
        lo = 0
        for p, s in zip(self.parts, self.scales):
            hi = lo + p.shape[0]
            m = (rows >= lo) & (rows < hi)
            if m.any():
                local = rows[m] - lo
                vals[m] = np.asarray(p[local])
                scale[m] = np.asarray(s[local])
            lo = hi
        return vals, scale

    def _gather(self, rows: np.ndarray) -> np.ndarray:
        return dequantize_rows(*self._gather_q(rows))

    def take_q(self, rows) -> Tuple[np.ndarray, np.ndarray]:
        """Raw row gather: (int8 values (n, d), f32 scales (n,))."""
        return self._gather_q(self._norm_rows(rows))


# backward-compat flag so callers can branch without isinstance checks
ShardedEmbeddings.is_quantized = False


class PrecomputedStore:
    def __init__(self, root, dim: int, emb_dtype="float16",
                 shard_rows: int = SHARD_ROWS):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.dim = dim
        self.emb_dtype = np.dtype(emb_dtype)
        self.shard_rows = shard_rows
        self.count = 0
        self.shards: List[dict] = []
        self.manifest_extra: dict = {}
        # "w+": this is the CREATE path — a build killed before its first
        # flush leaves a dirty text.jsonl with no manifest, and appending
        # after those orphans would bake dead rows into the fresh store
        # (reopen-for-append goes through open_, which truncates to the
        # committed watermark instead)
        self._text_f = open(self.root / "text.jsonl", "w+", encoding="utf-8")
        self._offsets: List[int] = []
        self._pending_embs: List[np.ndarray] = []
        # int8 stores: per-row scales parallel to _pending_embs (which then
        # holds already-quantized int8 batches — per-row quantization is
        # batching-independent, so quantize-at-ingest == quantize-on-flush)
        self._pending_scales: List[np.ndarray] = []
        self._pending_rows = 0
        # one shared file handle: seek+read / seek+write must be atomic
        self._lock = threading.Lock()

    @property
    def quantized(self) -> bool:
        return self.emb_dtype == np.int8

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        """Flush pending rows + manifest and release the text file handle.

        Idempotent; the store is unusable for reads/writes afterwards.
        """
        if self._text_f is not None and not self._text_f.closed:
            self.flush()
            self._text_f.close()

    def abort(self):
        """Release the text handle WITHOUT committing pending state —
        crash semantics for a failed build: the store on disk stays at
        its last flushed checkpoint, and a later ``open_`` truncates any
        uncommitted tail exactly as it would after a real kill."""
        if self._text_f is not None and not self._text_f.closed:
            self._text_f.close()

    @property
    def closed(self) -> bool:
        return self._text_f is None or self._text_f.closed

    def __enter__(self) -> "PrecomputedStore":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- write path ---------------------------------------------------------
    def add_batch(self, embs: np.ndarray, queries: Sequence[str],
                  responses: Sequence[str]):
        assert embs.shape == (len(queries), self.dim)
        with self._lock:
            self._text_f.seek(0, 2)
            for q, r in zip(queries, responses):
                self._offsets.append(self._text_f.tell())
                self._text_f.write(json.dumps({"q": q, "r": r}) + "\n")
            if self.quantized:
                qv, sc = quantize_rows(embs)
                self._pending_embs.append(qv)
                self._pending_scales.append(sc)
            else:
                self._pending_embs.append(embs.astype(self.emb_dtype))
            self._pending_rows += len(queries)
            self.count += len(queries)
            while self._pending_rows >= self.shard_rows:
                self._flush_shard(self.shard_rows)

    def _flush_shard(self, rows):
        buf = np.concatenate(self._pending_embs, axis=0)
        shard, rest = buf[:rows], buf[rows:]
        self._pending_embs = [rest] if len(rest) else []
        self._pending_rows = len(rest)
        name = f"emb_{len(self.shards):04d}.npy"
        # tmp + rename: a partial tail shard is REWRITTEN under the same
        # name on later flushes, and the committed manifest may already
        # reference it — a torn overwrite would corrupt the store
        self._atomic_npy(name, shard)
        entry = {"file": name, "rows": int(shard.shape[0])}
        if self.quantized:
            sbuf = np.concatenate(self._pending_scales)
            sshard, srest = sbuf[:rows], sbuf[rows:]
            self._pending_scales = [srest] if len(srest) else []
            sname = f"emb_{len(self.shards):04d}_scale.npy"
            self._atomic_npy(sname, sshard)
            entry["scale_file"] = sname
        self.shards.append(entry)

    def flush(self):
        with self._lock:
            if self._pending_rows:
                # merge pending rows into a trailing partial shard first:
                # checkpoint-heavy builds flush often, and cutting a tiny
                # shard per flush would fragment a paper-scale store into
                # hundreds of files. This keeps the layout a pure function
                # of the row count: full shards plus at most one tail.
                if self.shards and self.shards[-1]["rows"] < self.shard_rows:
                    last = self.shards.pop()
                    prev = np.load(self.root / last["file"])
                    self._pending_embs.insert(0, prev)
                    if self.quantized:
                        # per-row scales merge by plain concat — no
                        # dequant/requant, so the merged shard is byte-
                        # identical to one written in a single flush
                        self._pending_scales.insert(
                            0, np.load(self.root / last["scale_file"]))
                    self._pending_rows += last["rows"]
                while self._pending_rows >= self.shard_rows:
                    self._flush_shard(self.shard_rows)
                if self._pending_rows:
                    self._flush_shard(self._pending_rows)
            self._text_f.flush()
            text_bytes = os.fstat(self._text_f.fileno()).st_size
            # atomic commits (tmp + rename): a kill mid-flush leaves either
            # the old or the new file, never a torn one — that's what makes
            # resumable builds safe to restart from the manifest
            self._atomic_npy("offsets.npy",
                             np.asarray(self._offsets, np.int64))
            manifest = {"dim": self.dim, "count": self.count,
                        "emb_dtype": str(self.emb_dtype),
                        "shard_rows": self.shard_rows,
                        "text_bytes": text_bytes,
                        "shards": self.shards,
                        "extra": self.manifest_extra}
            tmp = self.root / "manifest.json.tmp"
            tmp.write_text(json.dumps(manifest))
            os.replace(tmp, self.root / "manifest.json")

    def _atomic_npy(self, name: str, arr: np.ndarray):
        tmp = self.root / (name + ".tmp.npy")
        np.save(tmp, arr)
        os.replace(tmp, self.root / name)

    # -- read path ------------------------------------------------------------
    @classmethod
    def open_(cls, root) -> "PrecomputedStore":
        root = Path(root)
        man = json.loads((root / "manifest.json").read_text())
        st = cls.__new__(cls)
        st.root = root
        st.dim = man["dim"]
        st.emb_dtype = np.dtype(man["emb_dtype"])
        st.shard_rows = man.get("shard_rows", SHARD_ROWS)
        st.count = man["count"]
        st.shards = man["shards"]
        st.manifest_extra = man.get("extra", {})
        # offsets may be one flush ahead of the manifest if a writer was
        # killed between the two renames — the manifest count is the commit
        # point, so drop any rows past it
        st._offsets = np.load(root / "offsets.npy").tolist()[:st.count]
        # "a+" (not "r"): a reopened store must keep serving appends —
        # §3.1 add_misses writes back into a store opened for reading.
        st._text_f = open(root / "text.jsonl", "a+", encoding="utf-8")
        text_bytes = man.get("text_bytes")
        if text_bytes is not None:
            st._text_f.seek(0, 2)
            if st._text_f.tell() > text_bytes:
                # trailing rows a killed writer appended but never committed
                st._text_f.truncate(text_bytes)
        st._pending_embs, st._pending_rows = [], 0
        st._pending_scales = []
        st._lock = threading.Lock()
        return st

    def embeddings(self, mmap: bool = True):
        """All embeddings, (count, dim): flushed shards plus pending rows.

        ``mmap=True`` (default) returns a zero-copy ``ShardedEmbeddings``
        view over the per-shard memmaps — nothing is materialized in RAM
        until a caller asks for rows. ``mmap=False`` returns a plain
        materialized ndarray. Quantized stores return a
        ``QuantizedShardedEmbeddings`` view (f32 on access, raw int8 +
        scales via its ``*_q`` accessors); ``mmap=False`` dequantizes.
        """
        mode = "r" if mmap else None
        parts = [np.load(self.root / s["file"], mmap_mode=mode)
                 for s in self.shards]
        if self._pending_embs:
            parts += self._pending_embs
        if self.quantized:
            scales = [np.load(self.root / s["scale_file"], mmap_mode=mode)
                      for s in self.shards] + self._pending_scales
            view = QuantizedShardedEmbeddings(parts, scales, self.dim)
            if not parts:
                return np.zeros((0, self.dim), np.float32)
            return view if mmap else np.asarray(view)
        if not parts:
            return np.zeros((0, self.dim), self.emb_dtype)
        if mmap:
            return ShardedEmbeddings(parts, self.dim, self.emb_dtype)
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    def get_pair(self, row: int) -> Tuple[str, str]:
        with self._lock:
            self._text_f.seek(self._offsets[row])
            line = self._text_f.readline()
        d = json.loads(line)
        return d["q"], d["r"]

    def get_response(self, row: int) -> str:
        return self.get_pair(row)[1]

    # -- accounting -----------------------------------------------------------
    def storage_bytes(self) -> dict:
        index_b = sum((self.root / s["file"]).stat().st_size
                      + ((self.root / s["scale_file"]).stat().st_size
                         if "scale_file" in s else 0)
                      for s in self.shards)
        text_p = self.root / "text.jsonl"
        off_p = self.root / "offsets.npy"
        meta_b = (text_p.stat().st_size if text_p.exists() else 0) \
            + (off_p.stat().st_size if off_p.exists() else 0)
        return {"index_bytes": index_b, "metadata_bytes": meta_b,
                "total_bytes": index_b + meta_b, "rows": self.count}
