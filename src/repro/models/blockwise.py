"""Blockwise (flash-style) attention in pure jnp, with a custom flash VJP.

Numerically identical to full softmax attention but never materializes the
(S, T) score matrix — in the FORWARD (scan over KV blocks carrying the
running (max, sum, out) triple) and, crucially, in the BACKWARD: plain JAX
AD through the KV scan would save the per-step attention probabilities
(= the full S x T matrix, observed 55 GB/device at 1M tokens), so
``blockwise_gqa`` registers the standard flash backward (save (q,k,v,out,lse)
only; recompute p tile-by-tile; ~2.5x forward attention FLOPs).

This is the memory-scalable attention used by train/prefill paths (32k+
contexts); the Pallas kernels in ``repro.kernels`` implement the same
contract for real-TPU execution, and this function doubles as their oracle
for big shapes.

FLOPs note: with ``causal=True`` the block grid is rectangular — fully
masked blocks still execute (~2x causal-optimal FLOPs). ``schedule="tri"``
(forward only) visits only j <= i blocks at the price of an O(n_q_blocks)
HLO. The §Perf log tracks this trade.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _id_constrain(t, b, h=None):
    return t


@dataclasses.dataclass(frozen=True)
class _Cfg:
    """Static tile-grid config (hashable: used as a nondiff custom_vjp arg)."""
    causal: bool
    scale: float
    mask_offset: int
    T: int                       # real (unpadded) KV length
    qb: int
    kb: int
    constrain: Callable = _id_constrain


def _q_pos(c, qi, qb):
    return c.mask_offset + qi * qb + jnp.arange(qb)


def _k_pos(c, kj, kb):
    return kj * kb + jnp.arange(kb)


def _scores(c, q_tile, k_tile, qi, kj):
    """(B,qb,Hkv,G,D) x (B,kb,Hkv,D) -> masked f32 (B,Hkv,G,qb,kb)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile, k_tile)
    s = s.astype(jnp.float32) * c.scale
    kp = _k_pos(c, kj, k_tile.shape[1])
    mask = (kp < c.T)[None, :]
    if c.causal:
        qp = _q_pos(c, qi, q_tile.shape[1])
        mask = mask & (kp[None, :] <= qp[:, None])
    return jnp.where(mask[None, None, None, :, :], s, NEG_INF)


# ---------------------------------------------------------------------------
# Tiled forward
# ---------------------------------------------------------------------------


def _fwd_tiles(c, qg, kg, vg):
    """qg: (nq,B,qb,Hkv,G,D); kg/vg: (nk,B,kb,Hkv,D[v]).

    Returns (out (nq,B,qb,Hkv,G,Dv), lse (nq,B,Hkv,G,qb) f32).
    """
    nq, B, qb, Hkv, G, D = qg.shape
    nk = kg.shape[0]
    Dv = vg.shape[-1]

    def q_block_body(args):
        qi, q_tile = args

        def step(carry, j):
            o, m, l = carry
            s = _scores(c, q_tile, kg[j], qi, j)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhv->bhgqv", p.astype(vg.dtype), vg[j])
            o_new = o * alpha[..., None].astype(o.dtype) + pv
            return (o_new, m_new, l_new), None

        o0 = c.constrain(jnp.zeros((B, Hkv, G, qb, Dv), vg.dtype), 0, 1)
        m0 = c.constrain(jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32), 0, 1)
        l0 = c.constrain(jnp.zeros((B, Hkv, G, qb), jnp.float32), 0, 1)
        (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), jnp.arange(nk))
        out = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return jnp.transpose(out, (0, 3, 1, 2, 4)), lse   # (B,qb,Hkv,G,Dv)

    out, lse = jax.lax.map(q_block_body, (jnp.arange(nq), qg))
    return out, lse


# ---------------------------------------------------------------------------
# Flash backward (recompute p per tile; no S x T materialization)
# ---------------------------------------------------------------------------


def _bwd_tiles(c, qg, kg, vg, lse, delta, dog):
    """Flash backward. dog: (nq,B,qb,Hkv,G,Dv) upstream grads.

    lse/delta: (nq,B,Hkv,G,qb) f32. Returns (dqg, dkg, dvg) in tile layout.
    """
    nq, B, qb, Hkv, G, D = qg.shape
    nk = kg.shape[0]
    dt = qg.dtype

    def p_ds(qi, kj, q_tile):
        s = _scores(c, q_tile, kg[kj], qi, kj)
        p = jnp.exp(s - lse[qi][..., None])               # (B,Hkv,G,qb,kb)
        do = dog[qi]                                      # (B,qb,Hkv,G,Dv)
        dp = jnp.einsum("bqhgv,bkhv->bhgqk", do, vg[kj]).astype(jnp.float32)
        ds = p * (dp - delta[qi][..., None]) * c.scale
        return p, ds, do

    def dq_block(args):
        qi, q_tile = args

        def step(dq, kj):
            _, ds, _ = p_ds(qi, kj, q_tile)
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(dt), kg[kj])
            return dq, None

        dq0 = c.constrain(jnp.zeros_like(q_tile), 0, 2)
        dq, _ = jax.lax.scan(step, dq0, jnp.arange(nk))
        return dq

    def dkv_block(args):
        kj, k_tile, v_tile = args

        def step(carry, qi):
            dk, dv = carry
            q_tile = qg[qi]
            p, ds, do = p_ds(qi, kj, q_tile)
            dk = dk + jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(dt), q_tile)
            dv = dv + jnp.einsum("bhgqk,bqhgv->bkhv", p.astype(dt), do)
            return (dk, dv), None

        dk0 = c.constrain(jnp.zeros_like(k_tile), 0, 2)
        dv0 = c.constrain(jnp.zeros_like(v_tile), 0, 2)
        (dk, dv), _ = jax.lax.scan(step, (dk0, dv0), jnp.arange(nq))
        return dk, dv

    dqg = jax.lax.map(dq_block, (jnp.arange(nq), qg))
    dkg, dvg = jax.lax.map(dkv_block, (jnp.arange(nk), kg, vg))
    return dqg, dkg, dvg


# ---------------------------------------------------------------------------
# custom_vjp wrapper (operates on tile layout; padding handled by caller)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(c, qg, kg, vg):
    out, _ = _fwd_tiles(c, qg, kg, vg)
    return out


def _flash_fwd(c, qg, kg, vg):
    out, lse = _fwd_tiles(c, qg, kg, vg)
    return out, (qg, kg, vg, out, lse)


def _flash_bwd(c, res, dout):
    qg, kg, vg, out, lse = res
    delta = jnp.einsum("nbqhgv,nbqhgv->nbhgq",
                       dout.astype(jnp.float32), out.astype(jnp.float32))
    return _bwd_tiles(c, qg, kg, vg, lse, delta, dout)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def blockwise_gqa(q, k, v, *, causal=True, mask_offset=0, q_block=512,
                  kv_block=1024, schedule="rect", constrain=None):
    """q: (B,S,Hq,D) k,v: (B,T,Hkv,D[v]) -> (B,S,Hq,Dv).

    mask_offset: queries at global position ``mask_offset + i`` may attend
    keys at positions j <= mask_offset + i (must be a python int).
    constrain: optional fn(tensor, batch_dim) -> tensor applying a batch
    sharding constraint — without it GSPMD tends to reshard the tile scan
    onto heads and replicate the batch dim (observed 7x memory blowup).
    """
    constrain = constrain or _id_constrain
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv

    qb = min(q_block, S)
    kb = min(kv_block, T)
    nq = -(-S // qb)
    nk = -(-T // kb)
    S_pad, T_pad = nq * qb, nk * kb
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))

    qg = constrain(q.reshape(B, nq, qb, Hkv, G, D), 0, 3)
    qg = constrain(jnp.moveaxis(qg, 1, 0), 1, 3)           # (nq,B,qb,Hkv,G,D)
    kg = constrain(jnp.moveaxis(k.reshape(B, nk, kb, Hkv, D), 1, 0), 1, 3)
    vg = constrain(jnp.moveaxis(v.reshape(B, nk, kb, Hkv, Dv), 1, 0), 1, 3)

    c = _Cfg(causal=causal, scale=D ** -0.5, mask_offset=int(mask_offset),
             T=T, qb=qb, kb=kb, constrain=constrain)
    if schedule == "tri" and causal:
        out = _tri_fwd(c, qg, kg, vg)
    else:
        out = _flash(c, qg, kg, vg)
    out = constrain(jnp.moveaxis(out, 0, 1), 0, 3)         # (B,nq,qb,Hkv,G,Dv)
    out = constrain(out.reshape(B, S_pad, Hq, Dv), 0, 2)
    return out[:, :S]


def _tri_fwd(c, qg, kg, vg):
    """Causal-skip schedule: python loop over q tiles, inner scan j <= i.

    Exactly the causal FLOPs (the §Perf lever for prefill); forward-only —
    AD falls back to scan residuals, so use for inference paths.
    """
    nq, B, qb, Hkv, G, D = qg.shape
    nk = kg.shape[0]
    Dv = vg.shape[-1]
    outs = []
    for qi in range(nq):
        q_tile = qg[qi]

        def step(carry, j, qi=qi, q_tile=q_tile):
            o, m, l = carry
            s = _scores(c, q_tile, kg[j], qi, j)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhgqk,bkhv->bhgqv", p.astype(vg.dtype), vg[j])
            o_new = o * alpha[..., None].astype(o.dtype) + pv
            return (o_new, m_new, l_new), None

        o0 = c.constrain(jnp.zeros((B, Hkv, G, qb, Dv), vg.dtype), 0, 1)
        m0 = c.constrain(jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32), 0, 1)
        l0 = c.constrain(jnp.zeros((B, Hkv, G, qb), jnp.float32), 0, 1)
        # only kv tiles overlapping [0, (qi+1)*qb + mask_offset) contribute
        n_vis = min(nk, -(-((qi + 1) * c.qb + c.mask_offset) // c.kb))
        (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), jnp.arange(n_vis))
        out = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
        outs.append(jnp.transpose(out, (0, 3, 1, 2, 4)))
    return jnp.stack(outs, axis=0)                         # (nq,B,qb,...)


# ---------------------------------------------------------------------------
# Absorbed-MLA blockwise attention over the COMPRESSED cache (inference /
# memory-bound prefill experiments; no custom vjp — forward-only use).
# ---------------------------------------------------------------------------


def blockwise_mla(q_c, q_r, ckv, krope, *, v_up, scale, causal=True,
                  mask_offset=0, q_block=512, kv_block=1024):
    """q_c: (B,S,H,r) absorbed queries; q_r: (B,S,H,dr); ckv: (B,T,r);
    krope: (B,T,dr); v_up: (r,H,Dv). Returns (B,S,H,Dv).

    Logits l[t] = q_c . ckv[t] + q_r . krope[t]; values are the compressed
    ckv rows, expanded through v_up once at the end — the flash carry is
    (o_c (B,H,qb,r), m, l), r-dim not Dv-dim.
    """
    B, S, H, r = q_c.shape
    T = ckv.shape[1]
    dr = q_r.shape[-1]

    qb = min(q_block, S)
    kb = min(kv_block, T)
    nq, nk = -(-S // qb), -(-T // kb)
    S_pad, T_pad = nq * qb, nk * kb
    pad4 = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0), (0, 0)))
    pad3 = lambda x, n: jnp.pad(x, ((0, 0), (0, n), (0, 0)))
    if S_pad != S:
        q_c, q_r = pad4(q_c, S_pad - S), pad4(q_r, S_pad - S)
    if T_pad != T:
        ckv, krope = pad3(ckv, T_pad - T), pad3(krope, T_pad - T)

    qcg = q_c.reshape(B, nq, qb, H, r)
    qrg = q_r.reshape(B, nq, qb, H, dr)
    cg = jnp.moveaxis(ckv.reshape(B, nk, kb, r), 1, 0)
    kg = jnp.moveaxis(krope.reshape(B, nk, kb, dr), 1, 0)
    q_pos = mask_offset + jnp.arange(S_pad).reshape(nq, qb)
    k_pos = jnp.arange(T_pad).reshape(nk, kb)
    k_valid = k_pos < T

    def q_block_body(args):
        qi, qc_t, qr_t = args

        def step(carry, j):
            o, m, l = carry
            s = (jnp.einsum("bqhr,bkr->bhqk", qc_t, cg[j])
                 + jnp.einsum("bqhr,bkr->bhqk", qr_t, kg[j]))
            s = s.astype(jnp.float32) * scale
            mask = k_valid[j][None, :]
            if causal:
                mask = mask & (k_pos[j][None, :] <= q_pos[qi][:, None])
            s = jnp.where(mask[None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhqk,bkr->bhqr", p.astype(cg.dtype), cg[j])
            o_new = o * alpha[..., None].astype(o.dtype) + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, H, qb, r), ckv.dtype)
        m0 = jnp.full((B, H, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), jnp.arange(nk))
        o = o / jnp.maximum(l, 1e-30)[..., None].astype(o.dtype)
        return jnp.transpose(o, (0, 2, 1, 3))              # (B,qb,H,r)

    qc_tiles = jnp.moveaxis(qcg, 1, 0)
    qr_tiles = jnp.moveaxis(qrg, 1, 0)
    o_c = jax.lax.map(q_block_body, (jnp.arange(nq), qc_tiles, qr_tiles))
    o_c = jnp.moveaxis(o_c, 0, 1).reshape(B, S_pad, H, r)[:, :S]
    return jnp.einsum("bshr,rhv->bshv", o_c, v_up)
