"""Mixture-of-Experts FFN with capacity-bounded sort-free dispatch.

The local path (this file) computes exact top-k routing with a per-call token
capacity: tokens are scattered into an (E, C, d) buffer by (expert, rank)
slot, experts run as one batched matmul, and results are gathered back and
combined with renormalized router weights. Overflowing tokens are dropped
(standard capacity-factor semantics) — the residual stream carries them.

Distributed variants (expert-parallel all-to-all via shard_map) live in
``repro.distributed.moe_parallel``; they reuse these param layouts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp_init, mlp


def moe_init(key, cfg, dtype=None):
    d, ffe, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)

    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32) * (a ** -0.5)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),  # router kept in f32
        "experts": {"w1": ew(ks[1], d, ffe), "w3": ew(ks[2], d, ffe),
                    "w2": ew(ks[3], ffe, d)},
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.n_shared_experts * ffe, dtype=dtype)
    return p


def route(cfg, p, x2d):
    """x2d: (T, d) -> (weights (T,K), idx (T,K), router probs for aux loss).

    The matmul keeps x2d in compute dtype with f32 ACCUMULATION
    (preferred_element_type) instead of upcasting x2d — an f32 copy of the
    full activation would be saved for the router backward on every layer
    (XLA hoists it into the scan residual stack; measured GBs/device).
    """
    w_r = p["router"]["w"].astype(x2d.dtype)
    logits = jax.lax.dot_general(
        x2d, w_r, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    w = w / (w.sum(-1, keepdims=True) + 1e-9)
    return w, idx, probs


def load_balance_loss(cfg, probs, idx):
    """Switch-style aux loss: E * sum_e(f_e * p_e)."""
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                                  # (E,)
    fe = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
    return E * jnp.sum(me * fe)


def capacity(cfg, n_tokens):
    c = int(n_tokens * cfg.experts_per_tok / cfg.n_experts * cfg.moe_capacity_factor)
    return max(c, 8)


def dispatch_slots(cfg, idx, n_tokens):
    """Compute (slot, valid) for each (token, k) assignment.

    slot = expert_id * C + rank_within_expert; overflow gets an out-of-range
    slot so scatter/gather with mode='drop'/'fill' handles it.
    """
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = capacity(cfg, n_tokens)
    flat_e = idx.reshape(-1)                                      # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (T*K, E)
    rank = jnp.cumsum(onehot, axis=0) - onehot                    # exclusive
    rank = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    valid = rank < C
    slot = jnp.where(valid, flat_e * C + rank, E * C)             # E*C = drop
    return slot, valid, C


def expert_ffn(cfg, experts, buf):
    """buf: (E, C, d) -> (E, C, d) through gated-SiLU expert MLPs."""
    h1 = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, experts["w1"]))
    h3 = jnp.einsum("ecd,edf->ecf", buf, experts["w3"])
    return jnp.einsum("ecf,efd->ecd", h1 * h3, experts["w2"])


def moe_ffn(cfg, p, x):
    """x: (B, S, d) -> (y, aux_loss). Exact top-k with capacity drop."""
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    w, idx, probs = route(cfg, p, x2d)
    slot, valid, C = dispatch_slots(cfg, idx, T)
    E, K = cfg.n_experts, cfg.experts_per_tok

    xk = jnp.repeat(x2d, K, axis=0)                               # (T*K, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        xk * valid[:, None].astype(x.dtype), mode="drop")
    out = expert_ffn(cfg, p["experts"], buf.reshape(E, C, d)).reshape(E * C, d)
    yk = out.at[slot].get(mode="fill", fill_value=0)              # (T*K, d)
    yk = yk * valid[:, None].astype(x.dtype)
    y = jnp.sum(yk.reshape(T, K, d) * w[..., None].astype(x.dtype), axis=1)

    if cfg.n_shared_experts:
        y = y + mlp(cfg, p["shared"], x2d)
    return y.reshape(B, S, d), load_balance_loss(cfg, probs, idx)


# ---------------------------------------------------------------------------
# GShard-style grouped einsum dispatch (GSPMD-friendly: all matmuls).
# ---------------------------------------------------------------------------


def combine_tensor(cfg, idx, w, valid, C):
    """(g,K) expert ids + weights -> (g, E, C) combine weights (f32)."""
    E = cfg.n_experts
    # rank of each (token, k) within its expert, computed per group
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(rank, flat_e[:, None], axis=1)[:, 0]
    rank = rank.reshape(idx.shape)                                # (g, K)
    ok = valid & (rank < C)
    oh_e = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (g,K,E)
    oh_c = jax.nn.one_hot(rank, C, dtype=jnp.float32)             # (g,K,C)
    comb = jnp.einsum("gk,gke,gkc->gec",
                      w * ok.astype(jnp.float32), oh_e, oh_c)
    return comb


def moe_ffn_einsum(cfg, p, x, group_size=2048):
    """GShard-style dispatch: (groups, g, E, C) combine tensors + einsums.

    Shards cleanly under GSPMD (groups follow the token/batch sharding, the
    expert dim or d_ff can be TP-sharded). Preferred when experts are fat
    (grok: d_ff 32768) so dispatch FLOPs amortize; thin-expert models
    (deepseek) use the shard_map EP path in repro.distributed.moe_parallel.
    """
    B, S, d = x.shape
    T = B * S
    g = min(group_size, T)
    n_groups = T // g
    assert n_groups * g == T, (T, g)
    x2d = x.reshape(T, d)
    w, idx, probs = route(cfg, p, x2d)
    C = capacity(cfg, g)

    def one_group(xg, wg, ig):
        comb = combine_tensor(cfg, ig, wg, jnp.ones(ig.shape, bool), C)
        disp = (comb > 0).astype(xg.dtype)                        # (g,E,C)
        buf = jnp.einsum("gec,gd->ecd", disp, xg)                 # (E,C,d)
        out = expert_ffn(cfg, p["experts"], buf)                  # (E,C,d)
        return jnp.einsum("gec,ecd->gd", comb.astype(xg.dtype), out)

    y = jax.vmap(one_group)(x2d.reshape(n_groups, g, d),
                            w.reshape(n_groups, g, cfg.experts_per_tok),
                            idx.reshape(n_groups, g, cfg.experts_per_tok))
    y = y.reshape(T, d)
    if cfg.n_shared_experts:
        y = y + mlp(cfg, p["shared"], x2d)
    return y.reshape(B, S, d), load_balance_loss(cfg, probs, idx)
