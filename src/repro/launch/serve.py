"""Serving launcher: the StorInfer facade in front of any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --n-pairs 800 --n-queries 40

Opens (or builds, via the resumable batched pipeline) a precomputed store,
stands up the fallback engine for the chosen arch, and serves a query
stream two ways: the paper's sequential race (per-query hit rate +
latency), then the same stream through the staged serving pipeline
(``serve()``/``submit()``) reporting the decoupled hit/miss latency
percentiles and per-stage queue accounting. On real hardware pass
--no-smoke to load the full arch config instead of the reduced smoke one.
"""
import argparse
import tempfile

import numpy as np

from repro.api import EngineCfg, StorInfer, SystemCfg
from repro.core.kb import build_kb, sample_user_queries
from repro.core.tokenizer import Tokenizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    # BooleanOptionalAction: plain store_true with default=True made the
    # full-config mode unreachable (--smoke could never be turned off)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced arch config (--no-smoke loads the full "
                         "one)")
    ap.add_argument("--dataset", default="squad")
    ap.add_argument("--n-pairs", type=int, default=800)
    ap.add_argument("--n-queries", type=int, default=40)
    ap.add_argument("--s-th-run", type=float, default=0.9)
    ap.add_argument("--index", choices=("auto", "flat", "ivf"),
                    default="auto",
                    help="auto picks the tier from store size and loads a "
                         "persisted IVF fit from the store root if present")
    ap.add_argument("--store", default=None,
                    help="store dir (default: temp, rebuilt)")
    ap.add_argument("--decode-slots", type=int, default=4,
                    help="persistent continuous-batching decode slots for "
                         "the staged serving pipeline")
    args = ap.parse_args()

    kb = build_kb(args.dataset, n_docs=20)
    tok = Tokenizer.from_texts([d.text() for d in kb.docs], max_vocab=2048)
    cfg = SystemCfg(index=args.index, s_th_run=args.s_th_run,
                    decode_slots=args.decode_slots,
                    engine=EngineCfg(arch=args.arch, smoke=args.smoke,
                                     max_len=160, chunk=8))

    store_dir = args.store or tempfile.mkdtemp(prefix="storinfer_")
    try:
        si = StorInfer.open(store_dir, cfg, tokenizer=tok)
        print(f"loaded store: {si.store.count} pairs")
    except FileNotFoundError:
        si = StorInfer.build(kb, cfg, store_dir, n_pairs=args.n_pairs,
                             tokenizer=tok)
        st = si.build_stats
        print(f"built store: {si.store.count} pairs "
              f"({st.discarded} discarded), "
              f"{si.store.storage_bytes()['total_bytes'] / 1e6:.2f} MB")

    with si:
        user = sample_user_queries(kb, args.n_queries, seed=9)
        hits, lat = 0, []
        for q, _ in user:
            r = si.query(q, max_new=16)
            hits += r.hit
            lat.append(r.latency_s)
        print(f"sequential race: hit_rate={hits / len(user):.3f} "
              f"mean_latency={np.mean(lat):.3f}s p50={np.median(lat):.3f}s")

        # the same stream through the staged pipeline: hits resolve at
        # search time, misses on the continuous-batching decode loop
        with si.serve():
            futs = [si.submit(q, max_new=16) for q, _ in user]
            results = [f.result(timeout=600) for f in futs]
        hit_lat = [r.latency_s for r in results if r.hit]
        miss_lat = [r.latency_s for r in results if not r.hit]
        parts = []
        if hit_lat:
            parts.append(f"hit_p50={np.median(hit_lat) * 1e3:.1f}ms")
        if miss_lat:
            parts.append(f"miss_p50={np.median(miss_lat) * 1e3:.1f}ms")
        print(f"staged pipeline: {' '.join(parts) or 'no queries'}")
        snap = si.stats().pipeline
        if snap:
            depth = {k: v["items"] for k, v in snap["stages"].items()}
            print(f"  stage items: {depth}  "
                  f"search_batches={snap['search_batches']}")


if __name__ == "__main__":
    main()
