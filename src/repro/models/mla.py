"""Multi-head Latent Attention (DeepSeek-V2), with weight-absorbed decode.

Prefill/train: expand the compressed c_kv back to per-head K/V (naive path).
Decode: absorb W_uk into the query and attend directly over the compressed
cache (c_kv ‖ k_rope) — per-token cost O(T·(r + d_rope)·H) instead of
O(T·(d_nope+d_rope)·H + T·r·H·d), the trick that makes MLA serve-efficient.
The cache stores only (c_kv: r, k_rope: d_rope) per token (576 for V2-Lite).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (dense, dense_init, rmsnorm, rmsnorm_init,
                                 apply_rope, causal_mask)


def mla_init(key, cfg, dtype=None):
    d = cfg.d_model
    H = cfg.n_heads
    nope, rope_d, vd, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                           cfg.v_head_dim, cfg.kv_lora_rank)
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * (nope + rope_d), dtype),
        "wdkv": dense_init(ks[1], d, r + rope_d, dtype),
        "ckv_norm": rmsnorm_init(r, dtype),
        "wuk": (jax.random.normal(ks[2], (r, H, nope), jnp.float32)
                * (r ** -0.5)).astype(dtype),
        "wuv": (jax.random.normal(ks[3], (r, H, vd), jnp.float32)
                * (r ** -0.5)).astype(dtype),
        "wo": dense_init(ks[4], H * vd, d, dtype),
    }
    return p


def _project_q(cfg, p, x):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = dense(p["wq"], x).reshape(B, S, H, nope + rope_d)
    return q[..., :nope], q[..., nope:]


def _project_ckv(cfg, p, x, positions):
    """Returns (c_kv normalized (B,S,r), k_rope roped (B,S,1,rope_d))."""
    r, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dkv = dense(p["wdkv"], x)
    ckv = rmsnorm(p["ckv_norm"], dkv[..., :r], cfg.norm_eps)
    krope = dkv[..., None, r:]  # single shared rope head
    krope = apply_rope(krope, positions, cfg.rope_theta)
    return ckv, krope


def mla_attention(cfg, p, x, positions, *, mask_offset=0):
    """Train/prefill path: expand compressed KV to per-head K/V."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qn, qr = _project_q(cfg, p, x)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv, krope = _project_ckv(cfg, p, x, positions)
    kn = jnp.einsum("bsr,rhn->bshn", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhn->bshn", ckv, p["wuv"])
    scale = (nope + rope_d) ** -0.5
    mask = causal_mask(S, S, mask_offset)[:, 0]  # (1,1,S,T)
    logits = (jnp.einsum("bshn,bthn->bhst", qn, kn)
              + jnp.einsum("bshr,btr->bhst", qr, krope[:, :, 0, :]))
    logits = logits.astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(v.dtype)
    out = jnp.einsum("bhst,bthv->bshv", probs, v)
    out_kv = {"ckv": ckv, "krope": krope[:, :, 0, :]}
    return dense(p["wo"], out.reshape(B, S, H * vd)), out_kv


def mla_decode(cfg, p, x, cache, cache_len, positions):
    """Absorbed decode: attend over the compressed cache directly.

    cache: {"ckv": (B, Smax, r), "krope": (B, Smax, rope_d)}.
    """
    B, S, _ = x.shape  # S == 1
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qn, qr = _project_q(cfg, p, x)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv_new, krope_new = _project_ckv(cfg, p, x, positions)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, cache_len, 0))
    krope = jax.lax.dynamic_update_slice(
        cache["krope"], krope_new[:, :, 0, :], (0, cache_len, 0))
    # absorb W_uk into the query: q_c = qn @ W_uk^T  -> (B,S,H,r)
    q_c = jnp.einsum("bshn,rhn->bshr", qn, p["wuk"])
    scale = (nope + rope_d) ** -0.5
    T = ckv.shape[1]
    logits = (jnp.einsum("bshr,btr->bhst", q_c, ckv)
              + jnp.einsum("bshr,btr->bhst", qr, krope))
    logits = logits.astype(jnp.float32) * scale
    mask = (jnp.arange(T)[None, :] <= cache_len)[None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(ckv.dtype)
    # attend over compressed values then expand once: (B,S,H,r) @ W_uv
    out_c = jnp.einsum("bhst,btr->bshr", probs, ckv)
    out = jnp.einsum("bshr,rhv->bshv", out_c, p["wuv"])
    new_cache = {"ckv": ckv, "krope": krope}
    return dense(p["wo"], out.reshape(B, S, H * vd)), new_cache
