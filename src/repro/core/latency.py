"""Latency models + measurement helpers (Fig 3 / Table 1 reproduction).

Two latency sources are reported side by side in EXPERIMENTS.md:
  * MEASURED — wall-clock of our CPU-scale components (vector search over
    the real store; tiny-LM inference through the JAX engine).
  * MODELED  — the paper's H100 operating point and the TPU v5e target,
    from a standard two-phase analytic model:
        prefill_time = 2 * N * C / (peak_flops * mfu)
        decode_time  = n_out * bytes(N) / hbm_bw   (memory-bound decode)
    which reproduces Fig 3's trend (LLM latency grows with context size,
    vector search flat).

``effective_latency`` implements the paper's §4 definition verbatim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass(frozen=True)
class HwPoint:
    name: str
    peak_flops: float          # dense (f16/bf16) FLOP/s
    hbm_bw: float              # bytes/s
    mfu_prefill: float = 0.45
    kv_bytes_per_tok: float = 0.0


H100 = HwPoint("h100-sxm", 989e12, 3.35e12)
V5E = HwPoint("tpu-v5e", 197e12, 819e9)


def llm_latency(hw: HwPoint, n_params: float, ctx_tokens: int,
                out_tokens: int, dtype_bytes: float = 2.0) -> dict:
    prefill = 2.0 * n_params * ctx_tokens / (hw.peak_flops * hw.mfu_prefill)
    per_tok = (n_params * dtype_bytes
               + hw.kv_bytes_per_tok * ctx_tokens) / hw.hbm_bw
    decode = out_tokens * per_tok
    return {"prefill_s": prefill, "decode_s": decode,
            "total_s": prefill + decode}


def effective_latency(hit_rate: float, search_s: float, llm_s: float):
    """Paper §4: hit*search + miss*llm (parallel execution makes the miss
    path cost exactly the plain-LLM latency)."""
    return hit_rate * search_s + (1.0 - hit_rate) * llm_s


def measure(fn: Callable, *args, repeat: int = 5, warmup: int = 2) -> dict:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    import numpy as np
    return {"mean_s": float(np.mean(ts)), "p50_s": float(np.median(ts)),
            "min_s": float(np.min(ts)), "max_s": float(np.max(ts))}
