"""Per-cell (architecture x input-shape x mesh) lowering plans for the
dry-run: ShapeDtypeStruct inputs (never allocated), sharding assignments,
and the step function to lower.

Shape kinds map to functions:
  train_*    -> train_step   (fwd+bwd+AdamW, microbatch accumulation)
  prefill_*  -> prefill      (full forward + cache construction)
  decode_* / long_* -> serve_step (one token against a seq_len KV cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed import sharding as Sh
from repro.launch.mesh import batch_axes_of
from repro.models import model as M
from repro.training import optimizer as O
from repro.training import train as T

# Per-arch gradient-accumulation defaults for train_4k (1M-token global
# batch): chosen so activations fit 16 GB/chip (see EXPERIMENTS.md §Dry-run).
TRAIN_ACCUM = {
    "qwen2-vl-72b": 8, "grok-1-314b": 8, "qwen2.5-32b": 8,
    "starcoder2-7b": 4, "deepseek-v2-lite-16b": 2, "llama3.2-3b": 2,
    "qwen3-1.7b": 2, "zamba2-1.2b": 8, "whisper-base": 1, "mamba2-130m": 1,
    "storinfer-paper-8b": 2, "storinfer-paper-1b": 1,
}

# Megatron-style sequence parallelism on the residual stream for train:
# halves activation memory (measured qwen3: 6.4 -> 2.9 GB/dev) at the cost
# of extra gathers around attention — enabled where fitting 16 GB needs it.
TRAIN_SP = {"starcoder2-7b", "qwen2.5-32b", "qwen2-vl-72b"}
# NOTE: pinning SSD internals to batch-only sharding was tested and
# REFUTED (§Perf mamba2 iteration 1: collectives 0.55 -> 1.49 s — GSPMD's
# speculative seq-sharding of the conv/SSD was net-positive); empty set.
PREFILL_PIN_SSM: set = set()

# Train-time SSD tile override (exact at any size; smaller tile = smaller
# intra-chunk (Q x Q) decay buffers in the unrolled-38-layer zamba2 grads).
TRAIN_SSM_CHUNK = {"zamba2-1.2b": 128}
# Prefill: the (B, n_chunks, H, Q, Q) intra-chunk decay matrix at Q=256 is
# ~17 GB/layer at 32k on a single pod; Q=64 is exact and 16x smaller.
PREFILL_SSM_CHUNK = {"zamba2-1.2b": 64}

FULL_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec")


def skip_reason(cfg, shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip(full-attn): 500k decode needs sub-quadratic attention"
    return None


def make_runcfg(cfg, shape, mesh, **overrides) -> M.RunCfg:
    kind = shape.kind
    moe_impl = "scatter"
    if cfg.family == "moe":
        ep_ok = (mesh is not None and "model" in mesh.axis_names
                 and cfg.n_experts % mesh.shape["model"] == 0
                 and kind in ("train", "prefill")
                 and shape.seq_len % mesh.shape["model"] == 0)
        moe_impl = "ep" if ep_ok else "einsum"
    decode_attn = "naive"
    if (kind == "decode" and mesh is not None
            and "model" in mesh.axis_names
            and shape.seq_len % mesh.shape["model"] == 0):
        decode_attn = "seq_sharded"
    q_ok, kv_ok = Sh.heads_shardable(cfg, mesh) if mesh is not None \
        else (False, False)
    kw = dict(
        attn_impl="blockwise",
        schedule="rect",
        q_block=512 if shape.seq_len >= 4096 else 256,
        kv_block=1024 if shape.seq_len >= 4096 else 256,
        moe_impl=moe_impl,
        moe_group=2048,
        remat=(kind == "train"),
        scan_layers=True,
        decode_attn=decode_attn,
        mesh=mesh,
        batch_axes=batch_axes_of(mesh) if mesh is not None else ("data",),
        heads_sharded=q_ok,
        repeat_kv=(q_ok and not kv_ok and not cfg.use_mla),
    )
    kw.update(overrides)
    return M.RunCfg(**kw)


# ---------------------------------------------------------------------------
# Input structs
# ---------------------------------------------------------------------------


def batch_struct(cfg, B, S, *, labels=True) -> Dict[str, Any]:
    sd = jax.ShapeDtypeStruct
    out = {"tokens": sd((B, S), jnp.int32)}
    if labels:
        out["labels"] = sd((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = sd((B, cfg.encoder_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    if cfg.rope_kind == "mrope":
        out["mrope_positions"] = sd((3, B, S), jnp.int32)
    return out


def params_struct(cfg):
    return jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Cell plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape_name: str
    kind: str
    fn: Any                     # positional fn to jit
    arg_structs: tuple
    in_shardings: Any
    out_shardings: Any
    cfg: Any
    run: Any
    notes: Dict[str, Any]
    donate: tuple = ()          # donate_argnums (train: params+opt alias)


def build_cell(arch: str, shape_name: str, mesh, *, cfg=None,
               run_overrides=None, accum=None) -> CellPlan:
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    run_overrides = dict(run_overrides or {})
    # "_dp_only": hillclimb sharding mode — no tensor parallelism; the
    # model axis joins data parallelism (batch/256) with ZeRO-3 param
    # gathers. Wins when d_model is too small to amortize TP psums.
    dp_only = run_overrides.pop("_dp_only", False)
    run = make_runcfg(cfg, shape, mesh, **run_overrides)
    B, S = shape.global_batch, shape.seq_len
    if dp_only:
        run = run.replace(batch_axes=tuple(mesh.axis_names),
                          heads_sharded=False, repeat_kv=False,
                          moe_impl="scatter" if cfg.family == "moe"
                          else run.moe_impl)
    ps = params_struct(cfg)
    pspec = Sh.param_specs(ps, mesh, cfg)
    if dp_only:  # strip "model" from every param spec (FSDP-only)
        from jax.sharding import PartitionSpec as PS

        def strip(spec):
            return PS(*[None if ax == "model" else ax for ax in spec])

        pspec = jax.tree_util.tree_map(
            strip, pspec, is_leaf=lambda x: isinstance(x, PS))
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspec)
    notes: Dict[str, Any] = {"moe_impl": run.moe_impl,
                             "decode_attn": run.decode_attn,
                             "heads_sharded": run.heads_sharded,
                             "repeat_kv": run.repeat_kv}

    if shape.kind == "train":
        if arch in TRAIN_SP and "seq_parallel" not in run_overrides:
            run = run.replace(seq_parallel=True)
            notes["seq_parallel"] = True
        if accum is None:
            accum = TRAIN_ACCUM.get(arch, 1)
            # TRAIN_ACCUM is calibrated for the 512-chip multi-pod mesh;
            # smaller meshes hold 2x the activations per chip -> scale up,
            # capped by per-shard batch divisibility.
            scale = max(1, 512 // max(mesh.size, 1))
            shards = 1
            for a in mesh.axis_names:
                if a != "model":
                    shards *= mesh.shape[a]
            accum = min(accum * scale, max(B // shards, 1))
        notes["accum"] = accum
        if arch in TRAIN_SSM_CHUNK and (run_overrides or {}).get(
                "ssm_chunk") is None:
            run = run.replace(ssm_chunk=TRAIN_SSM_CHUNK[arch])
            notes["ssm_chunk"] = run.ssm_chunk
        bs = batch_struct(cfg, B, S)
        bshard = Sh.batch_shardings(bs, mesh)
        if dp_only:
            all_ax = tuple(mesh.axis_names)

            def dp_batch(struct):
                spec = [None] * len(struct.shape)
                bdim = 1 if len(struct.shape) == 3 and \
                    struct.shape[0] == 3 else 0
                if struct.shape[bdim] % mesh.size == 0:
                    spec[bdim] = all_ax
                return NamedSharding(mesh, P(*spec))

            bshard = jax.tree_util.tree_map(dp_batch, bs)
        os_ = jax.eval_shape(O.init, ps)
        oshard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), O.state_specs(pspec))
        step = T.make_train_step(cfg, run, O.AdamWCfg(), accum=accum)
        mshard = NamedSharding(mesh, P())
        return CellPlan(arch, shape_name, "train", step, (ps, os_, bs),
                        (pshard, oshard, bshard),
                        (pshard, oshard, mshard), cfg, run, notes,
                        donate=(0, 1))

    if shape.kind == "prefill":
        if arch in PREFILL_SSM_CHUNK and (run_overrides or {}).get(
                "ssm_chunk") is None:
            run = run.replace(ssm_chunk=PREFILL_SSM_CHUNK[arch])
            notes["ssm_chunk"] = run.ssm_chunk
        if arch in PREFILL_PIN_SSM and "pin_ssm" not in run_overrides:
            run = run.replace(pin_ssm=True)
            notes["pin_ssm"] = True
        bs = batch_struct(cfg, B, S, labels=False)
        bshard = Sh.batch_shardings(bs, mesh)
        cs = jax.eval_shape(
            lambda p, b: M.prefill(cfg, p, b, run, max_len=S), ps, bs)[1]
        cshard = Sh.cache_shardings(cs, mesh)
        lshard = NamedSharding(mesh, Sh.spec_for(
            (B, 1, cfg.vocab_size), [Sh.BATCH, Sh.REP, Sh.TP], mesh))

        def fn(params, batch):
            return M.prefill(cfg, params, batch, run, max_len=S)

        return CellPlan(arch, shape_name, "prefill", fn, (ps, bs),
                        (pshard, bshard), (lshard, cshard), cfg, run, notes)

    # decode
    cs = M.cache_struct(cfg, B, S)
    cshard = Sh.cache_shardings(cs, mesh)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tshard = Sh.batch_shardings({"tokens": tok}, mesh)["tokens"]
    clen = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, token, cache, cache_len):
        return M.serve_step(cfg, params, token, cache, cache_len, None, run,
                            temperature=0.0)

    return CellPlan(arch, shape_name, "decode", fn, (ps, tok, cs, clen),
                    (pshard, tshard, cshard, NamedSharding(mesh, P())),
                    (tshard, cshard), cfg, run, notes,
                    donate=(2,))  # serving aliases the KV cache in place


def probe_depths(cfg):
    """(cfg_d1, cfg_d2, full_stack, stack_at_d1) for layer extrapolation."""
    base = cfg.first_dense_layers if cfg.family == "moe" else 0
    full_stack = cfg.n_layers - base
    d1 = dataclasses.replace(cfg, n_layers=base + 1)
    d2 = dataclasses.replace(cfg, n_layers=base + 2)
    return d1, d2, full_stack, 1
