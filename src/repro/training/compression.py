"""Int8 gradient compression with error feedback.

Distributed-optimization trick for slow cross-pod links: gradients are
quantized to int8 (per-tensor symmetric scale) BEFORE the gradient
all-reduce, and the quantization residual is carried in an error-feedback
buffer added to the next step's gradient — preserving convergence
(Seide et al. / EF-SGD). 4x less gradient traffic on the "pod" axis.

In the GSPMD program the all-reduce is compiler-inserted, so compression is
expressed as quantize -> dequantize around the point where the gradient
becomes replicated; XLA then reduces the int8 representation. The unit test
checks the EF invariant: sum of applied grads == sum of true grads up to
the final residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Returns (decompressed grads as seen by every worker, new_err)."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_int8(g)
        dq = dequantize(q, s)
        return dq, g - dq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]))
