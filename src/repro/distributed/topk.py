"""Distributed top-k for the mesh-sharded MIPS index.

The precomputed-query embedding matrix is row-sharded over the "model" axis;
each device scans its shard (one matmul — the Pallas ``mips_topk`` kernel on
real TPUs), takes a local top-k, then an all-gather of the (k-sized)
candidate lists and a final top-k. Traffic per query: shards * k * 8 bytes —
independent of store size N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map


def sharded_mips_topk(queries, emb, k, *, mesh, shard_axis="model",
                      local_scan=None):
    """queries: (Q, D) replicated; emb: (N, D) row-sharded over shard_axis.

    Returns (scores (Q, k), indices (Q, k)) — replicated, GLOBAL row ids.
    ``local_scan(q, e, k) -> (vals, idx)`` optionally overrides the local
    shard scan (e.g. with the Pallas kernel); default is matmul + lax.top_k.
    """

    def default_scan(q, e, k):
        s = q.astype(jnp.float32) @ e.T.astype(jnp.float32)
        return jax.lax.top_k(s, k)

    scan = local_scan or default_scan

    def local(q, e):
        offset = jax.lax.axis_index(shard_axis) * e.shape[0]
        v, i = scan(q, e, k)
        i = i + offset
        vg = jax.lax.all_gather(v, shard_axis, axis=1, tiled=True)
        ig = jax.lax.all_gather(i, shard_axis, axis=1, tiled=True)
        vf, pos = jax.lax.top_k(vg, k)
        return vf, jnp.take_along_axis(ig, pos, axis=1)

    sm = shard_map(local, mesh=mesh, in_specs=(P(), P(shard_axis)),
                       out_specs=(P(), P()), check_vma=False)
    return sm(queries, emb)
